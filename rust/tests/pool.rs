//! Worker-pool and SIMD-microkernel integration tests: bitwise parity
//! of the blocked cores against naive ascending-order oracles across
//! SIMD on/off × thread widths 1/2/8 × ragged shapes, persistent-pool
//! lifecycle stress (resize/shutdown/re-entrancy/panic), pool metrics,
//! and end-to-end decode parity with the microkernel forced scalar.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use misa::obs::metrics;
use misa::runtime::{Engine, Session};
use misa::serve::{generate, GenerateCfg, SamplerCfg};
use misa::tensor::par::Pool;
use misa::tensor::{gemm_nn, gemm_nt, gemm_tn_acc, set_simd, set_threads, Mat};
use misa::util::Rng;

/// The thread knob, SIMD mode, and metrics registry are process-global;
/// serialize every test so cargo's parallel harness cannot interleave
/// their state.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Naive oracles in the committed accumulation order: each output
// element reduces in strictly ascending reduction index, one f32
// rounding per mul and per add. The blocked + packed + SIMD cores
// promise to be bit-identical to exactly this.
// ---------------------------------------------------------------------------

fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

fn naive_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        for j in 0..k {
            let mut acc = 0.0f32;
            for t in 0..n {
                acc += a[i * n + t] * b[j * n + t];
            }
            out[i * k + j] = acc;
        }
    }
    out
}

fn naive_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for kk in 0..k {
        for j in 0..n {
            let mut acc = out[kk * n + j];
            for i in 0..m {
                acc += a[i * k + kk] * b[i * n + j];
            }
            out[kk * n + j] = acc;
        }
    }
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// The headline determinism claim of the SIMD microkernel: every core
/// is bit-identical to the naive ascending-order oracle with SIMD on
/// and off, at thread widths 1, 2, and 8 (8 oversubscribes every CI
/// runner — stealing and task order shuffle, results must not),
/// across shapes ragged against the KC/NC tiles and the 16-row task
/// granularity.
#[test]
fn cores_match_naive_bitwise_across_simd_and_thread_widths() {
    let _g = lock();
    let mut rng = Rng::new(83);
    for &(m, k, n) in
        &[(65, 63, 129), (1, 130, 7), (67, 1, 131), (3, 5, 1), (70, 129, 65), (97, 161, 133)]
    {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose(); // [n, k]
        let c = Mat::randn(m, n, 1.0, &mut rng);
        let want_nn = naive_nn(&a.data, &b.data, m, k, n);
        let want_nt = naive_nt(&a.data, &bt.data, m, k, n);
        let mut want_tn = vec![0.25f32; k * n];
        naive_tn_acc(&a.data, &c.data, m, k, n, &mut want_tn);
        for threads in [1usize, 2, 8] {
            for simd in [false, true] {
                set_threads(threads);
                set_simd(Some(simd));
                let label = format!("{m}x{k}x{n} t={threads} simd={simd}");
                let nn = gemm_nn(&a.data, &b.data, m, k, n);
                assert_bits_eq(&nn, &want_nn, &format!("gemm_nn {label}"));
                let nt = gemm_nt(&a.data, &bt.data, m, k, n);
                assert_bits_eq(&nt, &want_nt, &format!("gemm_nt {label}"));
                let mut tn = vec![0.25f32; k * n];
                gemm_tn_acc(&a.data, &c.data, m, k, n, &mut tn);
                assert_bits_eq(&tn, &want_tn, &format!("gemm_tn_acc {label}"));
            }
        }
        set_threads(0);
        set_simd(None);
    }
}

/// Pool lifecycle stress on a private instance: grow, shrink, shutdown,
/// reuse after shutdown, and a race loop of dispatches — every task
/// executes exactly once no matter how the participants interleave.
#[test]
fn pool_stress_resize_shutdown_and_exactly_once_execution() {
    let _g = lock();
    let pool = Pool::new();
    for round in 0..200usize {
        // cycle the resident width so grow/shrink races with dispatch
        match round % 10 {
            0 => pool.resize(3),
            3 => pool.resize(1),
            6 => pool.resize(4),
            9 => pool.resize(0),
            _ => {}
        }
        let n_tasks = 1 + round % 37;
        let counts: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, n_tasks, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "round {round}: task {i}");
        }
    }
    pool.shutdown();
    assert_eq!(pool.workers(), 0);
    // reusable after shutdown: inline on the caller…
    let hits = AtomicUsize::new(0);
    pool.run(4, 9, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 9);
    // …and with workers again after a respawn
    pool.resize(2);
    assert_eq!(pool.workers(), 2);
    let hits = AtomicUsize::new(0);
    pool.run(3, 50, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 50);
    pool.shutdown();
}

/// Re-entrancy: a task may call back into `Pool::run` (directly, or
/// transitively through a parallel GEMM, which shares the process
/// global pool) — nested dispatches execute inline on the task's
/// thread instead of deadlocking on the single in-flight job slot.
#[test]
fn nested_dispatch_from_inside_a_task_runs_inline() {
    let _g = lock();
    let pool = Pool::new();
    pool.resize(2);
    let inner_hits = AtomicUsize::new(0);
    pool.run(3, 6, |_| {
        pool.run(3, 5, |_| {
            inner_hits.fetch_add(1, Ordering::Relaxed);
        });
    });
    assert_eq!(inner_hits.load(Ordering::Relaxed), 30);
    // a parallel-sized GEMM inside a pool task must also complete (it
    // re-enters through the global pool's dispatch path)
    set_threads(4);
    let (m, k, n) = (97, 64, 64);
    let a = vec![0.5f32; m * k];
    let b = vec![0.25f32; k * n];
    let want = gemm_nn(&a, &b, m, k, n); // computed on the caller
    let done = AtomicUsize::new(0);
    pool.run(2, 3, |_| {
        let got = gemm_nn(&a, &b, m, k, n);
        assert_bits_eq(&got, &want, "gemm inside pool task");
        done.fetch_add(1, Ordering::Relaxed);
    });
    set_threads(0);
    assert_eq!(done.load(Ordering::Relaxed), 3);
    pool.shutdown();
}

/// A panicking task must not hang the dispatch or poison the pool: the
/// panic resurfaces on the submitting thread after the job drains, and
/// the pool keeps working afterwards.
#[test]
fn task_panic_propagates_to_the_submitter_and_pool_survives() {
    let _g = lock();
    let pool = Pool::new();
    pool.resize(2);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run(3, 8, |i| {
            if i == 5 {
                panic!("task 5 exploded");
            }
        });
    }));
    assert!(r.is_err(), "task panic must propagate out of run()");
    let hits = AtomicUsize::new(0);
    pool.run(3, 12, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 12, "pool unusable after a task panic");
    pool.shutdown();
}

/// The pool's batched observability: task and busy-time counters
/// accumulate in the global registry, and the worker gauge tracks the
/// resident count.
#[test]
fn pool_metrics_land_in_the_registry() {
    let _g = lock();
    metrics::reset();
    let pool = Pool::new();
    pool.resize(2);
    pool.run(3, 64, |i| {
        std::hint::black_box(i);
    });
    assert_eq!(metrics::counter("pool.tasks"), 64);
    assert_eq!(metrics::gauge("pool.workers"), Some(2.0));
    pool.run(3, 36, |i| {
        std::hint::black_box(i);
    });
    assert_eq!(metrics::counter("pool.tasks"), 100, "counters accumulate across runs");
    pool.shutdown();
}

/// End-to-end: decode is bit-identical with the SIMD microkernel on
/// and off, serial and fanned out — the serving stack may not observe
/// which inner kernel or how many threads did the math.
#[test]
fn generation_is_bit_identical_with_simd_on_and_off() {
    let _g = lock();
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 3).unwrap();
    let prompt = vec![1i32, 30, 31, 32, 30, 31, 32, 30, 31];
    let cfg = GenerateCfg {
        max_new: 12,
        sampler: SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 },
        seed: 13,
        eos: None,
        spec: None,
    };
    set_simd(Some(false));
    set_threads(1);
    let base = generate(&sess, &prompt, &cfg).unwrap();
    for threads in [1usize, 4] {
        for simd in [false, true] {
            set_threads(threads);
            set_simd(Some(simd));
            let got = generate(&sess, &prompt, &cfg).unwrap();
            assert_eq!(
                got.tokens, base.tokens,
                "decode diverged at threads={threads} simd={simd}"
            );
        }
    }
    set_threads(0);
    set_simd(None);
}
