//! Serving-subsystem integration tests: KV-cache numerics parity with
//! the uncached training forward, ring-buffer behavior, scheduler
//! end-to-end runs, and the train → checkpoint → generate round trip —
//! all on the default host backend, artifact-free.

use misa::coordinator::ckpt;
use misa::modelspec::Manifest;
use misa::runtime::{init_params, Backend, Engine, HostBackend, KvCache, Session};
use misa::serve::{generate, GenerateCfg, Request, SamplerCfg, Scheduler, SchedulerCfg};
use misa::util::Rng;

/// The `tiny` builtin model with randomly initialized parameters, plus
/// a direct `HostBackend` for the uncached reference path.
fn tiny_backend() -> (HostBackend, Vec<Vec<f32>>) {
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let host = init_params(&spec, 42);
    (HostBackend::new(spec).unwrap(), host)
}

fn random_prompt(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut p = vec![1i32]; // BOS
    while p.len() < len {
        p.push(rng.range(4, vocab) as i32);
    }
    p
}

/// Serializes the tests that sweep the process-global GEMM thread
/// knob: without it, cargo's parallel test harness could drop one
/// test's `threads = 4` leg back to 1 mid-flight (results stay
/// bit-identical either way, but the multi-threaded coverage would be
/// silently lost).
static THREAD_KNOB: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Acceptance criterion: greedy incremental decode must produce logits
/// within 1e-5 of running the full uncached forward on the growing
/// sequence, position by position.
#[test]
fn kv_cache_decode_matches_uncached_forward() {
    let (be, host) = tiny_backend();
    let vocab = 256usize;
    let prompt = random_prompt(6, vocab, 7);
    let n_new = 12;
    let mut cache = KvCache::new(
        &Manifest::builtin().model("tiny").unwrap().clone(),
        prompt.len() + n_new,
    )
    .unwrap();

    // prefill logits == last row of the uncached forward over the prompt
    let cached = be.prefill(&host, &prompt, &mut cache).unwrap();
    let full = be.full_logits(&host, &prompt).unwrap();
    let last = &full[(prompt.len() - 1) * vocab..];
    assert_eq!(cached.len(), vocab);
    for (a, b) in cached.iter().zip(last) {
        assert!((a - b).abs() < 1e-5, "prefill logits diverge: {a} vs {b}");
    }

    // greedy decode, re-checking against the growing uncached sequence
    let mut seq = prompt.clone();
    let mut logits = cached;
    for step in 0..n_new {
        let next = misa::serve::argmax(&logits) as i32;
        seq.push(next);
        logits = be.decode_step(&host, next, cache.len(), &mut cache).unwrap();
        let full = be.full_logits(&host, &seq).unwrap();
        let last = &full[(seq.len() - 1) * vocab..];
        let mut max_err = 0.0f32;
        for (a, b) in logits.iter().zip(last) {
            max_err = max_err.max((a - b).abs());
            assert!((a - b).abs() < 1e-5, "step {step}: cached {a} vs uncached {b}");
        }
        // the argmaxes must agree exactly, not just within tolerance
        assert_eq!(
            misa::serve::argmax(&logits),
            misa::serve::argmax(last),
            "step {step}: argmax diverged (max |Δ| {max_err})"
        );
    }
}

/// Chunked prefill (prompt split across two prefill calls) must match
/// one-shot prefill.
#[test]
fn chunked_prefill_matches_one_shot() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let prompt = random_prompt(9, 256, 21);
    let mut one = KvCache::new(&spec, 16).unwrap();
    let a = be.prefill(&host, &prompt, &mut one).unwrap();
    let mut two = KvCache::new(&spec, 16).unwrap();
    be.prefill(&host, &prompt[..4], &mut two).unwrap();
    let b = be.prefill(&host, &prompt[4..], &mut two).unwrap();
    assert_eq!(one.len(), two.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

/// A multi-token chunk that wraps the ring must match feeding the same
/// tokens one at a time: per-position write-then-attend ordering means
/// wrapping writes never clobber a slot an earlier in-chunk query still
/// needs.
#[test]
fn wrapping_chunked_prefill_matches_per_token_decode() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let toks = random_prompt(8, 256, 55);
    let capacity = 6; // positions 6, 7 wrap onto slots 0, 1
    let mut step = KvCache::new(&spec, capacity).unwrap();
    let mut want = Vec::new();
    for &tk in &toks {
        want = be.prefill(&host, &[tk], &mut step).unwrap();
    }
    let mut chunked = KvCache::new(&spec, capacity).unwrap();
    be.prefill(&host, &toks[..4], &mut chunked).unwrap();
    let got = be.prefill(&host, &toks[4..], &mut chunked).unwrap();
    assert_eq!(chunked.len(), step.len());
    for (x, y) in got.iter().zip(&want) {
        assert!((x - y).abs() < 1e-5, "wrapping chunk diverged: {x} vs {y}");
    }
}

/// Once past capacity the ring degrades to sliding-window attention:
/// decode keeps working, stays finite, and RoPE still uses absolute
/// positions (so logits differ from a fresh short-context run).
#[test]
fn ring_wraparound_decodes_past_capacity() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let mut cache = KvCache::new(&spec, 6).unwrap();
    let prompt = random_prompt(4, 256, 33);
    let mut logits = be.prefill(&host, &prompt, &mut cache).unwrap();
    for _ in 0..10 {
        let next = misa::serve::argmax(&logits) as i32;
        logits = be.decode_step(&host, next, cache.len(), &mut cache).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
    }
    assert_eq!(cache.len(), 14); // absolute positions keep advancing
    assert_eq!(cache.capacity(), 6);
}

#[test]
fn decode_rejects_non_contiguous_position() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let mut cache = KvCache::new(&spec, 8).unwrap();
    be.prefill(&host, &[1, 2, 3], &mut cache).unwrap();
    let err = be.decode_step(&host, 4, 7, &mut cache).unwrap_err();
    assert!(format!("{err:#}").contains("contiguous"), "{err:#}");
    // cache from a different model shape is rejected
    let small = Manifest::builtin().model("small").unwrap().clone();
    let mut wrong = KvCache::new(&small, 8).unwrap();
    assert!(be.prefill(&host, &[1, 2], &mut wrong).is_err());
    // a chunk longer than the cache capacity is rejected
    let mut short = KvCache::new(&spec, 2).unwrap();
    assert!(be.prefill(&host, &[1, 2, 3], &mut short).is_err());
}

/// Train a few steps, checkpoint, reload, generate — the round trip the
/// CI smoke job drives through the CLI, with determinism pinned: the
/// same (checkpoint, prompt, seed) triple must regenerate identical
/// tokens across independent sessions.
#[test]
fn train_checkpoint_generate_roundtrip_is_deterministic() {
    use misa::config::RunConfig;
    use misa::coordinator::Trainer;

    let mut eng = Engine::host();
    let rc = RunConfig {
        model: "tiny".into(),
        steps: 3,
        ..RunConfig::default()
    };
    let mut t = Trainer::new(&mut eng, rc).unwrap();
    t.run(3).unwrap();
    let path = std::env::temp_dir().join(format!("misa_serve_rt_{}.bin", std::process::id()));
    ckpt::save(&path, &t.sess.host).unwrap();

    let spec = eng.manifest.model("tiny").unwrap().clone();
    let cfg = GenerateCfg {
        max_new: 10,
        sampler: SamplerCfg { temperature: 0.7, top_k: 24, top_p: 0.9 },
        seed: 5,
        ..GenerateCfg::default()
    };
    let mut outs = Vec::new();
    for _ in 0..2 {
        let params = ckpt::load(&path).unwrap();
        let mut eng2 = Engine::host();
        let sess = Session::with_params(&mut eng2, spec.clone(), params).unwrap();
        outs.push(generate(&sess, &[1, 40, 41], &cfg).unwrap().tokens);
    }
    assert_eq!(outs[0], outs[1], "generation must be seed-reproducible");
    assert_eq!(outs[0].len(), 10);
    let _ = std::fs::remove_file(&path);
}

/// Continuous batching at the Session level: mixed-length requests all
/// complete, and each one's tokens are independent of batch composition.
#[test]
fn scheduler_end_to_end_over_session() {
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 3).unwrap();
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 3,
        token_budget: 128,
        ..SchedulerCfg::default()
    });
    let mk = |id: u64, plen: usize, max_new: usize| Request {
        id,
        prompt: random_prompt(plen, 256, 100 + id),
        max_new,
        sampler: SamplerCfg { temperature: 0.8, top_k: 12, top_p: 0.95 },
        seed: 900 + id,
        eos: None,
    };
    let reqs = [mk(0, 3, 9), mk(1, 7, 4), mk(2, 2, 12), mk(3, 5, 6), mk(4, 4, 7)];
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let mut done = sched.run(&sess).unwrap();
    assert_eq!(done.len(), reqs.len());
    assert!(sched.peak_active() >= 2);
    done.sort_by_key(|c| c.id);
    for (c, r) in done.iter().zip(&reqs) {
        assert_eq!(c.tokens.len(), r.max_new);
        let solo = generate(
            &sess,
            &r.prompt,
            &GenerateCfg {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
                eos: r.eos,
                ..GenerateCfg::default()
            },
        )
        .unwrap();
        assert_eq!(c.tokens, solo.tokens, "request {} depends on batch composition", r.id);
    }
}

/// Tentpole acceptance: batched decode over N concurrent streams must
/// match N independent per-slot `decode_step` runs within 1e-5 — at
/// `threads = 1` and `threads = 4`, and including a slot whose ring
/// buffer wraps mid-decode. (The implementation is bit-identical by
/// construction; the tolerance is the contract.)
#[test]
fn decode_batch_matches_per_slot_steps_across_thread_counts() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let vocab = 256usize;
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|i| random_prompt(3 + 2 * i, vocab, 70 + i as u64))
            .collect();
        // slot 2 gets capacity 8: its 7-token prompt still prefills in
        // one chunk, then the ring wraps during the 10 decode steps
        // below (7 + 10 > 8) — sliding-window attention on one slot
        // of an otherwise unwrapped batch
        let caps = [32usize, 32, 8];
        let mut batched: Vec<KvCache> = Vec::new();
        let mut solo: Vec<KvCache> = Vec::new();
        let mut last: Vec<i32> = Vec::new();
        for (p, &cap) in prompts.iter().zip(&caps) {
            let mut cb = KvCache::new(&spec, cap).unwrap();
            let logits = be.prefill(&host, p, &mut cb).unwrap();
            batched.push(cb);
            let mut cs = KvCache::new(&spec, cap).unwrap();
            be.prefill(&host, p, &mut cs).unwrap();
            solo.push(cs);
            last.push(misa::serve::argmax(&logits) as i32);
        }
        for step in 0..10 {
            let positions: Vec<usize> = batched.iter().map(|c| c.len()).collect();
            let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
            let rows = be.decode_batch(&host, &last, &positions, &mut refs).unwrap();
            assert_eq!(rows.len(), 3);
            for (slot, row) in rows.iter().enumerate() {
                let want = be
                    .decode_step(&host, last[slot], solo[slot].len(), &mut solo[slot])
                    .unwrap();
                let mut max_err = 0.0f32;
                for (a, b) in row.iter().zip(&want) {
                    max_err = max_err.max((a - b).abs());
                }
                assert!(
                    max_err < 1e-5,
                    "threads={threads} step={step} slot={slot}: batched decode \
                     diverged (max |Δ| {max_err})"
                );
                assert_eq!(
                    misa::serve::argmax(row),
                    misa::serve::argmax(&want),
                    "threads={threads} step={step} slot={slot}: argmax diverged"
                );
            }
            for (slot, row) in rows.iter().enumerate() {
                last[slot] = misa::serve::argmax(row) as i32;
            }
        }
        // the wrapping slot really wrapped
        assert!(batched[2].len() > batched[2].capacity());
    }
    misa::tensor::set_threads(0);
}

/// Scheduled (batched) generation must equal solo generation for every
/// request, independent of the GEMM worker-pool width — N concurrent
/// prompts through the scheduler against N solo `generate` runs at
/// `threads = 1` and `threads = 4`.
#[test]
fn scheduler_batched_decode_matches_solo_at_thread_counts() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 9).unwrap();
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                prompt: random_prompt(2 + i as usize, 256, 40 + i),
                max_new: 5 + i as usize,
                sampler: SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 },
                seed: 700 + i,
                eos: None,
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 256,
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        assert!(sched.peak_active() >= 2, "decode must actually batch");
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            let solo = generate(
                &sess,
                &r.prompt,
                &GenerateCfg {
                    max_new: r.max_new,
                    sampler: r.sampler,
                    seed: r.seed,
                    eos: r.eos,
                    ..GenerateCfg::default()
                },
            )
            .unwrap();
            assert_eq!(
                c.tokens, solo.tokens,
                "threads={threads}: request {} depends on batch composition", r.id
            );
        }
    }
    misa::tensor::set_threads(0);
}

/// Tentpole acceptance: decode from a cache forked at a mid-prompt
/// point (suffix prefilled on top of the shared prefix) must match a
/// cold prefill of the full prompt within 1e-5, step by step — prefix
/// reuse changes what is recomputed, never what is computed.
#[test]
fn forked_cache_decode_matches_cold_prefill() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let prompt = random_prompt(12, 256, 91);
    // parent: the full prompt, as a prompt-cache entry would hold it
    let mut parent = KvCache::new(&spec, 32).unwrap();
    be.prefill(&host, &prompt, &mut parent).unwrap();
    // fork at a mid-prompt point, prefill only the novel suffix
    let m = 7;
    let mut fork = KvCache::fork_from(&parent, m).unwrap();
    assert_eq!(fork.len(), m);
    let forked = be.prefill(&host, &prompt[m..], &mut fork).unwrap();
    // cold: the same capacity, the full prompt from scratch
    let mut cold = KvCache::new(&spec, 32).unwrap();
    let want = be.prefill(&host, &prompt, &mut cold).unwrap();
    assert_eq!(fork.len(), cold.len());
    for (a, b) in forked.iter().zip(&want) {
        assert!((a - b).abs() < 1e-5, "forked prefill diverged: {a} vs {b}");
    }
    assert_eq!(misa::serve::argmax(&forked), misa::serve::argmax(&want));
    // greedy decode both streams for 8 steps
    let (mut fl, mut cl) = (forked, want);
    for step in 0..8 {
        let next = misa::serve::argmax(&cl) as i32;
        assert_eq!(misa::serve::argmax(&fl) as i32, next, "step {step}");
        fl = be.decode_step(&host, next, fork.len(), &mut fork).unwrap();
        cl = be.decode_step(&host, next, cold.len(), &mut cold).unwrap();
        let mut max_err = 0.0f32;
        for (a, b) in fl.iter().zip(&cl) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-5, "step {step}: forked decode diverged (max |Δ| {max_err})");
    }
    // the fork's writes never leaked into the parent (copy-on-write):
    // it still decodes from its own tip as if never forked
    assert_eq!(parent.len(), prompt.len());
    let parent_decode = be.decode_step(&host, 3, parent.len(), &mut parent).unwrap();
    assert!(parent_decode.iter().all(|x| x.is_finite()));
}

/// A fork at the tip of a *wrapped* parent ring (sliding-window
/// regime) must still decode identically to a cold cache fed the same
/// tokens — and fork points the wrap has evicted are rejected.
#[test]
fn fork_past_ring_wraparound_matches_cold_prefill() {
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let toks = random_prompt(9, 256, 58);
    let capacity = 6; // positions 6, 7, 8 wrapped onto slots 0, 1, 2
    let mut parent = KvCache::new(&spec, capacity).unwrap();
    be.prefill(&host, &toks[..5], &mut parent).unwrap();
    let last = be.prefill(&host, &toks[5..], &mut parent).unwrap();
    assert!(parent.len() > parent.capacity(), "the ring must actually wrap");
    // fork points the wrap evicted are refused; the tip is forkable
    assert!(KvCache::fork_from(&parent, 5).is_err());
    let mut fork = KvCache::fork_from(&parent, parent.len()).unwrap();
    // cold reference: same capacity, same tokens, same chunking
    let mut cold = KvCache::new(&spec, capacity).unwrap();
    be.prefill(&host, &toks[..5], &mut cold).unwrap();
    let mut cl = be.prefill(&host, &toks[5..], &mut cold).unwrap();
    let mut fl = last;
    for step in 0..6 {
        let next = misa::serve::argmax(&cl) as i32;
        assert_eq!(misa::serve::argmax(&fl) as i32, next, "step {step}");
        fl = be.decode_step(&host, next, fork.len(), &mut fork).unwrap();
        cl = be.decode_step(&host, next, cold.len(), &mut cold).unwrap();
        let mut max_err = 0.0f32;
        for (a, b) in fl.iter().zip(&cl) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 1e-5,
            "step {step}: wrapped-fork decode diverged (max |Δ| {max_err})"
        );
    }
}

/// Tentpole acceptance: batched prefill over N ragged prompts must
/// match N sequential per-slot prefills within 1e-5 — at `threads = 1`
/// and `threads = 4`. (The stacked rows go through the same GEMM cores
/// and the same per-position attention kernel, so the implementation
/// is bit-identical by construction; the tolerance is the contract.)
#[test]
fn prefill_batch_matches_sequential_prefill_across_thread_counts() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|i| random_prompt(3 + 3 * i, 256, 200 + i as u64))
            .collect();
        let mut batched: Vec<KvCache> =
            prompts.iter().map(|_| KvCache::new(&spec, 32).unwrap()).collect();
        let rows = {
            let chunks: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
            let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
            be.prefill_batch(&host, &chunks, &mut refs).unwrap()
        };
        assert_eq!(rows.len(), prompts.len());
        for (slot, p) in prompts.iter().enumerate() {
            let mut solo = KvCache::new(&spec, 32).unwrap();
            let want = be.prefill(&host, p, &mut solo).unwrap();
            assert_eq!(batched[slot].len(), p.len());
            let mut max_err = 0.0f32;
            for (a, b) in rows[slot].iter().zip(&want) {
                max_err = max_err.max((a - b).abs());
            }
            assert!(
                max_err < 1e-5,
                "threads={threads} slot={slot}: batched prefill diverged \
                 (max |Δ| {max_err})"
            );
            assert_eq!(
                misa::serve::argmax(&rows[slot]),
                misa::serve::argmax(&want),
                "threads={threads} slot={slot}: argmax diverged"
            );
        }
        // the batched caches are decode-ready: one batched step works
        let tokens: Vec<i32> =
            rows.iter().map(|r| misa::serve::argmax(r) as i32).collect();
        let positions: Vec<usize> = batched.iter().map(|c| c.len()).collect();
        let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
        let step = be.decode_batch(&host, &tokens, &positions, &mut refs).unwrap();
        assert!(step.iter().flatten().all(|x| x.is_finite()));
    }
    misa::tensor::set_threads(0);
}

/// The scheduler's prefix cache on a shared-prefix workload: every
/// output still equals solo generation, and the reuse counters record
/// real forks.
#[test]
fn scheduler_prefix_cache_matches_solo_and_reports_reuse() {
    use misa::serve::CacheStoreCfg;
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 11).unwrap();
    let shared = random_prompt(10, 256, 321);
    let reqs: Vec<Request> = (0..5)
        .map(|i| {
            let mut p = shared.clone();
            p.extend([(60 + i) as i32, (70 + i) as i32]);
            Request {
                id: i,
                prompt: p,
                max_new: 6,
                sampler: SamplerCfg { temperature: 0.8, top_k: 12, top_p: 0.95 },
                seed: 400 + i,
                eos: None,
            }
        })
        .collect();
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 3,
        token_budget: 512,
        prefix_cache: Some(CacheStoreCfg { capacity: 64, max_entries: 8, min_prefix: 4 }),
        ..SchedulerCfg::default()
    });
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let mut done = sched.run(&sess).unwrap();
    assert_eq!(done.len(), reqs.len());
    done.sort_by_key(|c| c.id);
    for (c, r) in done.iter().zip(&reqs) {
        let solo = generate(
            &sess,
            &r.prompt,
            &GenerateCfg {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
                eos: r.eos,
                ..GenerateCfg::default()
            },
        )
        .unwrap();
        assert_eq!(
            c.tokens, solo.tokens,
            "request {}: prefix reuse changed the generated tokens", r.id
        );
    }
    let stats = sched.cache_stats().unwrap();
    assert!(stats.hits >= 4, "all but the first request should fork: {stats:?}");
    assert!(stats.reused_tokens >= 4 * shared.len() as u64, "{stats:?}");
    assert!(stats.hit_rate() > 0.0);
    assert_eq!(sched.in_flight_tokens(), 0);
}

/// KV memory accounting: GQA halves the cache relative to MHA head
/// count, and bytes() matches the documented closed form.
#[test]
fn kv_cache_memory_accounting() {
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let mc = &spec.config;
    let cache = KvCache::new(&spec, 64).unwrap();
    let want = 2 * mc.n_layers * 64 * mc.kv_dim() * 4;
    assert_eq!(cache.bytes(), want);
    assert_eq!(KvCache::bytes_for(&spec, 64), want);
    // tiny is GQA 4/2: kv_dim is half of dim
    assert_eq!(mc.kv_dim() * 2, mc.dim);
    assert!(cache.is_empty());
}

/// Tentpole acceptance: `verify_step`'s stacked multi-token forward
/// must match sequential `decode_step` logits at every draft position
/// — at `threads = 1` and `threads = 4` — and rolling a rejected
/// draft back with `truncate` must leave the slot exactly where it
/// was. (The implementation is bit-identical by construction; the
/// tolerance is the contract.)
#[test]
fn verify_step_matches_sequential_decode_and_rolls_back() {
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let vocab = 256usize;
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|i| random_prompt(3 + 2 * i, vocab, 140 + i as u64))
            .collect();
        // arbitrary ragged "draft" chunks of 2..=4 tokens per slot
        let chunks_tok: Vec<Vec<i32>> = (0..3)
            .map(|i| random_prompt(2 + i, vocab, 240 + i as u64))
            .collect();
        let mut vcaches: Vec<KvCache> = Vec::new();
        let mut rcaches: Vec<KvCache> = Vec::new();
        for p in &prompts {
            let mut cv = KvCache::new(&spec, 32).unwrap();
            be.prefill(&host, p, &mut cv).unwrap();
            vcaches.push(cv);
            let mut cr = KvCache::new(&spec, 32).unwrap();
            be.prefill(&host, p, &mut cr).unwrap();
            rcaches.push(cr);
        }
        let starts: Vec<usize> = vcaches.iter().map(|c| c.len()).collect();
        let rows = {
            let chunks: Vec<&[i32]> = chunks_tok.iter().map(|c| c.as_slice()).collect();
            let mut refs: Vec<&mut KvCache> = vcaches.iter_mut().collect();
            be.verify_step(&host, &chunks, &starts, &mut refs).unwrap()
        };
        for (slot, chunk) in chunks_tok.iter().enumerate() {
            assert_eq!(rows[slot].len(), chunk.len() * vocab);
            for (j, &tk) in chunk.iter().enumerate() {
                let want = be
                    .decode_step(&host, tk, rcaches[slot].len(), &mut rcaches[slot])
                    .unwrap();
                let got = &rows[slot][j * vocab..(j + 1) * vocab];
                let mut max_err = 0.0f32;
                for (a, b) in got.iter().zip(&want) {
                    max_err = max_err.max((a - b).abs());
                }
                assert!(
                    max_err < 1e-5,
                    "threads={threads} slot={slot} pos={j}: verify diverged \
                     (max |Δ| {max_err})"
                );
                assert_eq!(
                    misa::serve::argmax(got),
                    misa::serve::argmax(&want),
                    "threads={threads} slot={slot} pos={j}: argmax diverged"
                );
            }
        }
        // rollback: rejecting the whole draft must leave each slot
        // exactly where it was — the next real decode step matches a
        // stream that never speculated
        for (slot, &start) in starts.iter().enumerate() {
            assert_eq!(vcaches[slot].len(), start + chunks_tok[slot].len());
            vcaches[slot].truncate(start).unwrap();
            let mut fresh = KvCache::new(&spec, 32).unwrap();
            be.prefill(&host, &prompts[slot], &mut fresh).unwrap();
            let a = be
                .decode_step(&host, 7, vcaches[slot].len(), &mut vcaches[slot])
                .unwrap();
            let b = be.decode_step(&host, 7, fresh.len(), &mut fresh).unwrap();
            let mut max_err = 0.0f32;
            for (x, y) in a.iter().zip(&b) {
                max_err = max_err.max((x - y).abs());
            }
            assert!(
                max_err < 1e-5,
                "threads={threads} slot={slot}: post-rollback decode diverged \
                 (max |Δ| {max_err})"
            );
        }
    }
    misa::tensor::set_threads(0);
}

/// Tentpole acceptance: the speculative loop must emit exactly the
/// greedy sequential tokens on a slot whose ring buffer *wraps*
/// mid-stream — drafting backs off to single-token verification as the
/// ring fills (rollback past a wrap would be impossible), and
/// positions keep advancing in sliding-window attention. Run at
/// `threads = 1` and `threads = 4`.
#[test]
fn spec_decode_on_a_wrapping_ring_matches_sequential_greedy() {
    use misa::serve::spec::{accept, draft_budget, draft_chunk};
    use misa::serve::{DraftCtl, SamplerCfg, SpecCfg};
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let (be, host) = tiny_backend();
    let spec = Manifest::builtin().model("tiny").unwrap().clone();
    let capacity = 12;
    let max_new = 13usize;
    let prompt = vec![1, 8, 9, 8, 9]; // recurring bigram: drafting engages early
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        // sequential greedy reference on the same ring layout
        let mut rc = KvCache::new(&spec, capacity).unwrap();
        let mut rl = be.prefill(&host, &prompt, &mut rc).unwrap();
        let mut want = vec![misa::serve::argmax(&rl) as i32];
        while want.len() < max_new {
            let last = *want.last().unwrap();
            rl = be.decode_step(&host, last, rc.len(), &mut rc).unwrap();
            want.push(misa::serve::argmax(&rl) as i32);
        }
        assert!(rc.len() > rc.capacity(), "the reference ring must wrap");
        // speculative stream: draft, verify, accept, roll back
        let scfg = SpecCfg { draft_len: 3, ngram: 2 };
        let greedy = SamplerCfg::greedy();
        let mut ctl = DraftCtl::new(&scfg);
        let mut rng = Rng::new(0); // greedy draws nothing; the API needs a stream
        let mut vc = KvCache::new(&spec, capacity).unwrap();
        let vl = be.prefill(&host, &prompt, &mut vc).unwrap();
        let mut got = vec![misa::serve::argmax(&vl) as i32];
        let mut history = prompt.clone();
        history.extend_from_slice(&got);
        while got.len() < max_new {
            let remaining = max_new - got.len();
            let budget = draft_budget(ctl.draft_len(), vc.len(), vc.capacity(), remaining);
            let (chunk, drafts) = draft_chunk(&history, scfg.ngram, budget);
            let start = vc.len();
            let rows = {
                let mut refs = [&mut vc];
                be.verify_step(&host, &[chunk.as_slice()], &[start], &mut refs).unwrap()
            };
            let (emitted, accepted) = accept(&rows[0], 256, &drafts, &greedy, &mut rng);
            ctl.record(&scfg, drafts.len(), accepted);
            for &x in &emitted {
                got.push(x);
                history.push(x);
                if got.len() >= max_new {
                    break;
                }
            }
            vc.truncate(start + 1 + accepted).unwrap();
        }
        assert_eq!(got, want, "threads={threads}: speculation changed a wrapping stream");
        assert!(vc.len() > vc.capacity(), "the speculative ring must wrap too");
    }
    misa::tensor::set_threads(0);
}

/// Tentpole acceptance: scheduled speculative generation equals plain
/// solo generation for every request — greedy and seeded-sampled — at
/// `threads = 1` and `threads = 4`.
#[test]
fn spec_scheduler_matches_plain_solo_across_thread_counts() {
    use misa::serve::SpecCfg;
    let _knob = THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 21).unwrap();
    for &threads in &[1usize, 4] {
        misa::tensor::set_threads(threads);
        let reqs: Vec<Request> = (0..4)
            .map(|i| {
                let t = 30 + i as i32;
                Request {
                    id: i,
                    // recurring structure so the proposer has material
                    prompt: vec![1, t, t + 1, t, t + 1, t],
                    max_new: 6 + i as usize,
                    sampler: if i % 2 == 0 {
                        SamplerCfg::greedy()
                    } else {
                        SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 }
                    },
                    seed: 600 + i,
                    eos: None,
                }
            })
            .collect();
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 4,
            token_budget: 256,
            spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, r) in done.iter().zip(&reqs) {
            let solo = generate(
                &sess,
                &r.prompt,
                &GenerateCfg {
                    max_new: r.max_new,
                    sampler: r.sampler,
                    seed: r.seed,
                    eos: r.eos,
                    spec: None,
                },
            )
            .unwrap();
            assert_eq!(
                c.tokens, solo.tokens,
                "threads={threads}: request {} diverged under speculation", r.id
            );
        }
        let st = sched.spec_stats().unwrap();
        assert!(st.accepted <= st.drafted);
    }
    misa::tensor::set_threads(0);
}

// ---- differential property tests (fuzz-harness reference models) ----
//
// The `misa::fuzz` targets pit each serving core against a naive
// reference model after every op. Running them here under several
// fixed seeds turns them into ordinary property tests: KvCache vs a
// dense Vec-of-rows model (fork/truncate/copy legality, bitwise window
// reads, chunk-dedup residency), and the prompt trie vs a flat LCP
// scan (lookup choice, LRU eviction, stats counters).

#[test]
fn kvcache_matches_its_dense_reference_over_random_op_streams() {
    use misa::fuzz::{fuzz_kvcache, FuzzCfg};
    for seed in [1u64, 0xA5A5, 0xDEAD_BEEF] {
        let stats = fuzz_kvcache(FuzzCfg { seed, ops: 1200 }).unwrap();
        assert!(stats.checks as usize > stats.ops, "seed {seed:#x}: no invariant coverage");
        assert!(stats.count("fork") > 0, "seed {seed:#x}: stream never forked");
        assert!(stats.count("truncate") > 0, "seed {seed:#x}: stream never truncated");
    }
}

#[test]
fn prompt_trie_matches_a_flat_scan_reference_over_random_op_streams() {
    use misa::fuzz::{fuzz_trie, FuzzCfg};
    for seed in [2u64, 0x5A5A, 0xFEED_FACE] {
        let stats = fuzz_trie(FuzzCfg { seed, ops: 1000 }).unwrap();
        assert!(stats.count("insert_stored") > 0, "seed {seed:#x}: nothing was stored");
        assert!(stats.count("lookup_hit") > 0, "seed {seed:#x}: no lookup ever hit");
    }
}
