//! Observability integration tests: the histogram against a sorted-vec
//! oracle, span nesting across the persistent GEMM worker pool,
//! timeline ordering invariants through a real scheduler run, exporter
//! output, the flight recorder's panic-dump path, and — the headline
//! claim — bit-parity of every decode path with tracing, the sampling
//! profiler, and the flight recorder fully enabled.

use std::sync::Mutex;

use misa::obs::{metrics, span, Histogram, Timeline};
use misa::runtime::{Engine, Session};
use misa::serve::{
    generate, GenerateCfg, Request, SamplerCfg, Scheduler, SchedulerCfg, SpecCfg,
};
use misa::util::Rng;

/// Tracing, the span buffer, the metrics registry, and the GEMM thread
/// knob are process-global; serialize every test that touches them so
/// cargo's parallel harness cannot interleave their state.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_session(seed: u64) -> Session {
    let mut eng = Engine::host();
    Session::create(&mut eng, "tiny", seed).unwrap()
}

fn random_prompt(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut p = vec![1i32]; // BOS
    while p.len() < len {
        p.push(rng.range(4, vocab) as i32);
    }
    p
}

/// The log-bucketed histogram must track the exact order statistic
/// within one bucket ratio (2^(1/8) ≈ 9%) across six decades of
/// sample magnitude and a sweep of quantiles.
#[test]
fn histogram_percentiles_track_a_sorted_vec_oracle() {
    let mut rng = Rng::new(0x0B5E);
    let mut h = Histogram::new();
    let mut xs: Vec<f64> = Vec::with_capacity(5000);
    for _ in 0..5000 {
        // log-uniform over [1e-1, 1e5): microsecond blips to minute
        // stalls, all well above the underflow bucket
        let v = 10f64.powf(-1.0 + 6.0 * rng.f64());
        h.observe(v);
        xs.push(v);
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    let max_log_err = (2f64).ln() / 8.0 * 1.0001; // one bucket, in log space
    for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0] {
        let exact = misa::obs::percentile_exact(&xs, q);
        let approx = h.percentile(q);
        let log_err = (approx / exact).ln().abs();
        assert!(
            log_err <= max_log_err,
            "q={q}: histogram {approx} vs exact {exact} (log err {log_err})"
        );
    }
    assert_eq!(h.count(), 5000);
    assert!((h.min() - xs[0]).abs() < 1e-12);
    assert!((h.max() - xs[xs.len() - 1]).abs() < 1e-12);
}

/// A 4-way GEMM dispatch records one `gemm_nn` root plus one
/// `pool_task` child per row-block task, every one re-parented onto
/// the dispatch span — persistent pool workers have no inherited
/// thread-local stack, and which participant (a worker or the caller
/// itself, via stealing) executes a given task is scheduling-dependent,
/// so the per-task parent capture is what keeps the tree connected.
#[test]
fn pool_task_spans_attach_to_the_dispatch_span() {
    let _g = lock();
    span::enable_tracing();
    let _ = span::take_events(); // flush whatever ran before
    metrics::reset();
    misa::tensor::set_threads(4);
    // 256×64×64: 1M MACs clears the 32k-per-worker floor at width 4;
    // 256 rows at the 16-row task granularity → 16 row-block tasks
    let (m, k, n) = (256usize, 64usize, 64usize);
    let a = vec![0.5f32; m * k];
    let b = vec![0.25f32; k * n];
    let out = misa::tensor::gemm_nn(&a, &b, m, k, n);
    misa::tensor::set_threads(0); // back to the environment default
    let (evs, dropped) = span::take_events();
    span::disable_tracing();
    assert_eq!(out.len(), m * n);
    assert_eq!(dropped, 0);
    let roots: Vec<_> = evs.iter().filter(|e| e.name == "gemm_nn").collect();
    assert_eq!(roots.len(), 1, "one dispatch span: {evs:?}");
    assert_eq!(roots[0].depth, 0);
    assert_eq!(roots[0].cat, "tensor");
    let tasks: Vec<_> = evs.iter().filter(|e| e.name == "pool_task").collect();
    assert_eq!(tasks.len(), 16, "256 rows / 16-row blocks: {evs:?}");
    for t in &tasks {
        assert_eq!(t.parent, Some("gemm_nn"), "task lost its parent");
        assert_eq!(t.depth, 1);
        assert_eq!(t.cat, "pool");
        assert!(t.start_us >= roots[0].start_us);
        assert!(t.start_us + t.dur_us <= roots[0].start_us + roots[0].dur_us + 1);
    }
    // the pool's batched metrics saw the dispatch too
    assert_eq!(metrics::counter("pool.tasks"), 16);
    // structural sanity of the Chrome render (CI validates via python)
    let json = span::render_chrome_trace(&evs, 0);
    assert!(json.contains("\"traceEvents\""), "{json}");
    assert!(json.contains("\"pool_task\""), "{json}");
    assert!(json.contains("\"ph\":\"X\""), "{json}");
}

/// Timeline stamps must respect enqueue ≤ admit ≤ prefill ≤ first
/// token ≤ finish, and ITL bookkeeping must reject impossible states.
#[test]
fn timeline_ordering_invariants_hold_and_misuse_is_caught() {
    let mut tl = Timeline::start();
    tl.admit();
    tl.prefill_done();
    tl.mark_first_token();
    tl.emit(2);
    tl.emit(1);
    tl.finish();
    tl.validate().unwrap();
    assert_eq!(tl.itl_ms.len(), 3, "emit(2)+emit(1) → 3 per-token samples");
    assert!(tl.ttft_ms().unwrap() >= 0.0);
    // ITL samples without a first token are impossible through the API
    // (emit no-ops before mark_first_token) and rejected by validate
    let mut bad = Timeline::start();
    bad.emit(5);
    assert!(bad.itl_ms.is_empty(), "emit before first token must no-op");
    bad.itl_ms.push(1.0);
    assert!(bad.validate().is_err(), "orphan ITL sample must fail");
    // negative gaps are rejected too
    let mut neg = Timeline::start();
    neg.mark_first_token();
    neg.itl_ms.push(-1.0);
    assert!(neg.validate().is_err(), "negative ITL gap must fail");
}

/// A real scheduler run with tracing on: every hot-path span shows up,
/// per-request timelines pool into the scheduler's latency vectors,
/// the registry histograms fill, and the Prometheus dump carries the
/// precomputed quantiles.
#[test]
fn scheduler_run_records_spans_timelines_and_metrics() {
    let _g = lock();
    span::enable_tracing();
    let _ = span::take_events();
    metrics::reset();
    let sess = tiny_session(5);
    // spec pinned off so the non-speculative decode_tick path is the
    // one under test even when CI forces MISA_SPEC defaults on
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 2,
        token_budget: 128,
        spec: None,
        ..SchedulerCfg::default()
    });
    let reqs: Vec<Request> = (0..4u64)
        .map(|id| Request {
            id,
            prompt: random_prompt(3 + id as usize, 256, 40 + id),
            max_new: 6,
            sampler: SamplerCfg::greedy(),
            seed: 70 + id,
            eos: None,
        })
        .collect();
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let mut done = sched.run(&sess).unwrap();
    sched.publish_metrics();
    let (evs, dropped) = span::take_events();
    span::disable_tracing();
    assert_eq!(dropped, 0);
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), reqs.len());
    for c in &done {
        assert_eq!(
            c.itl_ms.len(),
            c.tokens.len() - 1,
            "request {}: one ITL sample per token after the first",
            c.id
        );
        assert!(c.itl_ms.iter().all(|&g| g >= 0.0 && g.is_finite()));
    }
    // pooled latencies: one TTFT per request, ITLs sum across requests
    let lat = sched.latencies();
    assert_eq!(lat.ttft_ms.len(), reqs.len());
    let total_itl: usize = done.iter().map(|c| c.itl_ms.len()).sum();
    assert_eq!(lat.itl_ms.len(), total_itl);
    let ttft = lat.ttft();
    assert_eq!(ttft.count, reqs.len());
    assert!(ttft.p50 <= ttft.p90 && ttft.p90 <= ttft.p99 && ttft.p99 <= ttft.max);
    // every hot path left its span
    for name in [
        "sched_tick",
        "admission",
        "prefill_rounds",
        "decode_tick",
        "ragged_forward",
        "decode_batch",
    ] {
        assert!(evs.iter().any(|e| e.name == name), "missing span {name:?}");
    }
    // the registry saw the run and the dump exposes the quantiles
    let h = metrics::histogram("serve.ttft_ms").expect("ttft histogram registered");
    assert_eq!(h.count() as usize, reqs.len());
    let h = metrics::histogram("serve.itl_ms").expect("itl histogram registered");
    assert_eq!(h.count() as usize, total_itl);
    assert_eq!(metrics::counter("serve.completions") as usize, reqs.len());
    let dump = metrics::prometheus_dump();
    assert!(dump.contains("# TYPE misa_serve_ttft_ms histogram"), "{dump}");
    assert!(dump.contains("misa_serve_ttft_ms_quantile{q=\"0.99\"}"), "{dump}");
    assert!(dump.contains("misa_serve_completions 4"), "{dump}");
    assert!(dump.contains("misa_serve_peak_active"), "{dump}");
}

/// Headline correctness claim: instrumentation must not perturb
/// determinism. With tracing fully enabled, speculative generation
/// still equals plain generation, scheduled generation still equals
/// solo generation, and thread counts 1 and 4 agree bit-for-bit with
/// the tracing-off baseline.
#[test]
fn decode_paths_are_bit_identical_with_tracing_enabled() {
    let _g = lock();
    let sess = tiny_session(9);
    let prompt = vec![1, 30, 31, 32, 30, 31, 32, 30, 31];
    let plain = GenerateCfg {
        max_new: 16,
        sampler: SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 },
        seed: 11,
        eos: None,
        spec: None,
    };
    let spec = GenerateCfg {
        spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
        ..plain.clone()
    };
    // baseline: tracing off, default threads
    span::disable_tracing();
    misa::tensor::set_threads(1);
    let base = generate(&sess, &prompt, &plain).unwrap();
    // solo requests for the scheduler leg, baseline tokens per request
    let reqs: Vec<Request> = (0..3u64)
        .map(|id| Request {
            id,
            prompt: random_prompt(4 + id as usize, 256, 300 + id),
            max_new: 8,
            sampler: SamplerCfg::greedy(),
            seed: 500 + id,
            eos: None,
        })
        .collect();
    let solo: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let cfg = GenerateCfg {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
                eos: r.eos,
                spec: None,
            };
            generate(&sess, &r.prompt, &cfg).unwrap().tokens
        })
        .collect();
    span::enable_tracing();
    for threads in [1usize, 4] {
        misa::tensor::set_threads(threads);
        let a = generate(&sess, &prompt, &plain).unwrap();
        let b = generate(&sess, &prompt, &spec).unwrap();
        assert_eq!(a.tokens, base.tokens, "tracing perturbed plain decode (t={threads})");
        assert_eq!(b.tokens, base.tokens, "tracing perturbed spec decode (t={threads})");
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 128,
            spec: None,
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, want) in done.iter().zip(&solo) {
            assert_eq!(
                &c.tokens, want,
                "tracing perturbed scheduled decode (t={threads}, id={})",
                c.id
            );
        }
    }
    misa::tensor::set_threads(0);
    let (evs, dropped) = span::take_events();
    span::disable_tracing();
    assert_eq!(dropped, 0);
    // the runs above really were traced
    for name in ["generate", "verify_step", "sched_tick"] {
        assert!(evs.iter().any(|e| e.name == name), "missing span {name:?}");
    }
}

/// The same parity matrix with the *whole* forensics stack live at
/// once — tracing, the sampling profiler (stack publication + kernel
/// timers on every GEMM), and the flight recorder — pinning that
/// profiling and forensics are computation-read-only too.
#[test]
fn decode_paths_are_bit_identical_with_profiling_and_flight_on() {
    let _g = lock();
    let sess = tiny_session(9);
    let prompt = vec![1, 30, 31, 32, 30, 31, 32, 30, 31];
    let plain = GenerateCfg {
        max_new: 16,
        sampler: SamplerCfg { temperature: 0.8, top_k: 16, top_p: 0.9 },
        seed: 11,
        eos: None,
        spec: None,
    };
    let spec = GenerateCfg {
        spec: Some(SpecCfg { draft_len: 4, ngram: 3 }),
        ..plain.clone()
    };
    // baseline: every obs facility off
    span::disable_tracing();
    misa::obs::flight::disable();
    misa::tensor::set_threads(1);
    let base = generate(&sess, &prompt, &plain).unwrap();
    let reqs: Vec<Request> = (0..3u64)
        .map(|id| Request {
            id,
            prompt: random_prompt(4 + id as usize, 256, 300 + id),
            max_new: 8,
            sampler: SamplerCfg::greedy(),
            seed: 500 + id,
            eos: None,
        })
        .collect();
    let solo: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let cfg = GenerateCfg {
                max_new: r.max_new,
                sampler: r.sampler,
                seed: r.seed,
                eos: r.eos,
                spec: None,
            };
            generate(&sess, &r.prompt, &cfg).unwrap().tokens
        })
        .collect();
    // now: spans recorded, sampler running hot, flight ring filling
    span::enable_tracing();
    misa::obs::profile::start(1000).unwrap();
    misa::obs::flight::enable();
    for threads in [1usize, 4] {
        misa::tensor::set_threads(threads);
        let a = generate(&sess, &prompt, &plain).unwrap();
        let b = generate(&sess, &prompt, &spec).unwrap();
        assert_eq!(a.tokens, base.tokens, "profiling perturbed plain decode (t={threads})");
        assert_eq!(b.tokens, base.tokens, "profiling perturbed spec decode (t={threads})");
        let mut sched = Scheduler::new(SchedulerCfg {
            max_slots: 2,
            token_budget: 128,
            spec: None,
            ..SchedulerCfg::default()
        });
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let mut done = sched.run(&sess).unwrap();
        done.sort_by_key(|c| c.id);
        for (c, want) in done.iter().zip(&solo) {
            assert_eq!(
                &c.tokens, want,
                "profiling perturbed scheduled decode (t={threads}, id={})",
                c.id
            );
        }
    }
    misa::tensor::set_threads(0);
    misa::obs::flight::disable();
    misa::obs::profile::stop();
    let (_evs, dropped) = span::take_events();
    span::disable_tracing();
    assert_eq!(dropped, 0);
    // the forensics really were live: the sampler ticked, the GEMM
    // kernel timers fed the roofline table, and scheduler ops landed
    // in the flight ring
    let rep = misa::obs::profile::report();
    assert!(rep.ticks > 0, "sampler never ticked");
    assert!(!rep.kernels.is_empty(), "no kernel call was timed");
    assert!(misa::obs::flight::recorded() > 0, "no flight events recorded");
}

/// Crash-forensics contract: a scheduler workload fills the flight
/// ring, and a forced panic afterwards leaves a well-formed JSON dump
/// (written by the panic hook) reconstructing hundreds of scheduler
/// operations in order.
#[test]
fn forced_panic_dumps_a_well_formed_flight_ring() {
    let _g = lock();
    let dump = std::env::temp_dir()
        .join(format!("misa_obs_flight_panic_{}.json", std::process::id()));
    misa::obs::flight::enable();
    misa::obs::flight::set_dump_path(&dump);
    misa::obs::flight::install_panic_hook();
    let before = misa::obs::flight::recorded();
    // a workload long enough that ticks + admissions + completions
    // alone clear the ≥256-operation forensics floor
    let sess = tiny_session(13);
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 2,
        token_budget: 256,
        spec: None,
        ..SchedulerCfg::default()
    });
    for id in 0..8u64 {
        sched
            .submit(Request {
                id,
                prompt: random_prompt(4, 256, 900 + id),
                max_new: 64,
                sampler: SamplerCfg::greedy(),
                seed: 900 + id,
                eos: None,
            })
            .unwrap();
    }
    let done = sched.run(&sess).unwrap();
    assert_eq!(done.len(), 8);
    let recorded = misa::obs::flight::recorded() - before;
    assert!(recorded >= 256, "scheduler run recorded only {recorded} flight events");
    // force a panic mid-"tick": the hook must write the dump before
    // unwinding reaches catch_unwind
    let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _sp = misa::span!("sched_tick", "serve");
        panic!("forced scheduler failure");
    }));
    assert!(boom.is_err());
    misa::obs::flight::disable();
    let body = std::fs::read_to_string(&dump).expect("panic hook wrote the flight dump");
    let doc = misa::util::Json::parse(&body).unwrap();
    let events = doc.arr_field("events").unwrap();
    assert!(events.len() >= 256, "dump holds only {} events", events.len());
    let mut prev = -1.0;
    for e in events {
        let seq = e.f64_field("seq").unwrap();
        assert!(seq > prev, "events out of order");
        prev = seq;
        e.f64_field("t_us").unwrap();
        e.str_field("kind").unwrap();
        e.str_field("name").unwrap();
    }
    // the ring reconstructs the scheduler's actual operations
    for name in ["tick", "admit", "complete"] {
        assert!(
            events.iter().any(|e| {
                e.str_field("kind").is_ok_and(|k| k == "sched")
                    && e.str_field("name").is_ok_and(|n| n == name)
            }),
            "missing sched event {name:?}"
        );
    }
    let _ = std::fs::remove_file(&dump);
}
