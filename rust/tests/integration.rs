//! Integration tests over the full runtime (backend subsystem, trainer,
//! optimizers) on the default **host backend** — no AOT artifacts
//! needed, so these run end-to-end in a fresh checkout. The PJRT path
//! has no coverage here: it needs real artifacts plus a real `xla`
//! binding, neither of which exists offline (`--features pjrt` builds
//! it against the stub but cannot execute it).
//!
//! The Adam *formula* itself is pinned independently by
//! `optim::adam::tests::host_adam_matches_reference_formula` and the
//! finite-difference checks in `tests/host_backend.rs`; the
//! kernel-vs-host tests below guard the Session/backend plumbing
//! (host-mirror coherence, return-value contract), which on the host
//! backend shares the update code by construction.

use misa::config::{DataSpec, MethodSpec, RunConfig};
use misa::coordinator::Trainer;
use misa::data::{Loader, TaskKind};
use misa::optim::{MisaConfig, SamplerConfig};
use misa::runtime::{Engine, Session};

fn engine() -> Engine {
    Engine::host()
}

#[test]
fn fwd_bwd_roundtrip_shapes_and_norms() {
    let mut eng = engine();
    let sess = Session::create(&mut eng, "tiny", 0).unwrap();
    let mc = sess.spec.config.clone();
    let mut loader = Loader::tasks(&TaskKind::ALL, mc.vocab, mc.batch, mc.seq_len, 1);
    let out = sess.fwd_bwd(&loader.next_batch()).unwrap();
    assert!(out.loss.is_finite());
    // random init ⇒ loss ≈ ln(V)
    assert!((out.loss - (mc.vocab as f32).ln()).abs() < 1.5, "loss {}", out.loss);
    assert_eq!(out.grads.len(), sess.spec.params.len());
    assert_eq!(out.sq_norms.len(), sess.spec.params.len());
    // the sq-norm by-product must equal the actual grad norms
    for (i, g) in out.grads.iter().enumerate() {
        let want: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let got = out.sq_norms[i] as f64;
        let tol = 1e-3 * want.max(1e-6);
        assert!((want - got).abs() <= tol, "param {i}: {want} vs {got}");
    }
}

#[test]
fn backend_adam_matches_host_adam() {
    // the backend's fused-Adam entry point must leave the session host
    // mirror and its return values coherent with the optimizer-side
    // host loop (the ref.py::adam_ref contract); on the host backend
    // the formula is shared, so this pins the *plumbing* — see the
    // module doc for where the formula itself is independently pinned
    let mut eng = engine();
    let mut sess = Session::create(&mut eng, "tiny", 0).unwrap();
    let mc = sess.spec.config.clone();
    let mut loader = Loader::tasks(&TaskKind::ALL, mc.vocab, mc.batch, mc.seq_len, 2);
    let out = sess.fwd_bwd(&loader.next_batch()).unwrap();
    let idx = sess.spec.matrix_module_indices()[0];
    let n = sess.spec.params[idx].numel();
    let p_before = sess.host[idx].clone();
    let (m_new, v_new, sq) = sess
        .adam_update(idx, &out.grads[idx], &vec![0.0; n], &vec![0.0; n], 1e-3)
        .unwrap();
    // host reference
    let mut p_ref = p_before.clone();
    let mut st = misa::optim::AdamState::zeros(n);
    st.step(&mut p_ref, &out.grads[idx], 1e-3, misa::optim::AdamHyper::default());
    for i in 0..n {
        assert!((sess.host[idx][i] - p_ref[i]).abs() < 1e-5, "p[{i}]");
        assert!((m_new[i] - st.m[i]).abs() < 1e-6, "m[{i}]");
        assert!((v_new[i] - st.v[i]).abs() < 1e-7, "v[{i}]");
    }
    let want_sq: f32 = out.grads[idx].iter().map(|&x| x * x).sum();
    assert!((sq - want_sq).abs() <= 1e-3 * want_sq.max(1e-6));
}

#[test]
fn predict_consistent_with_fwd_bwd_loss() {
    let mut eng = engine();
    let sess = Session::create(&mut eng, "tiny", 3).unwrap();
    let mc = sess.spec.config.clone();
    let mut loader = Loader::tasks(&TaskKind::ALL, mc.vocab, mc.batch, mc.seq_len, 5);
    let batch = loader.next_batch();
    let a = sess.fwd_bwd(&batch).unwrap();
    let b = sess.predict(&batch).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-4, "{} vs {}", a.loss, b.loss);
    assert_eq!(b.correct.len(), mc.batch * mc.seq_len);
}

#[test]
fn misa_training_reduces_loss_on_tiny() {
    let mut eng = engine();
    let cfg = RunConfig {
        model: "tiny".into(),
        method: MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.30, ..Default::default() },
            t_inner: 10,
            ..Default::default()
        }),
        data: DataSpec::Commonsense,
        lr: 3e-3,
        steps: 150,
        log_every: 25,
        ..Default::default()
    };
    let mut t = Trainer::new(&mut eng, cfg).unwrap();
    let first = t.step().unwrap();
    t.run(149).unwrap();
    let eval = t.evaluate(4).unwrap();
    // tiny model, frozen random embed/head: expect modest but real
    // progress (the meaningful accuracy experiments run from a
    // pre-trained base; see coordinator::experiments)
    assert!(
        (eval.loss as f32) < first * 0.97,
        "no progress: first {first} final {}",
        eval.loss
    );
}

#[test]
fn every_method_runs_a_few_steps() {
    let mut eng = engine();
    let methods: Vec<MethodSpec> = vec![
        MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.05, ..Default::default() },
            t_inner: 3,
            ..Default::default()
        }),
        MethodSpec::FullAdam,
        MethodSpec::BAdam { t_inner: 3 },
        MethodSpec::Lisa { t_inner: 3 },
        MethodSpec::Lora { rank: 4, alpha: 8.0 },
        MethodSpec::Dora { rank: 4, alpha: 8.0 },
        MethodSpec::Galore { rank: 4, update_freq: 5, scale: 0.25 },
        MethodSpec::LoraMisa { rank: 4, alpha: 8.0, delta: 0.5, eta: 1.0, t_inner: 3 },
    ];
    for m in methods {
        let label = m.label();
        let cfg = RunConfig {
            model: "tiny".into(),
            method: m,
            data: DataSpec::Math,
            lr: 1e-3,
            steps: 8,
            log_every: 100,
            ..Default::default()
        };
        let mut t = Trainer::new(&mut eng, cfg).unwrap();
        t.run(8).unwrap_or_else(|e| panic!("{label}: {e}"));
        let eval = t.evaluate(2).unwrap();
        assert!(eval.loss.is_finite(), "{label}");
        assert!(t.alloc.peak_bytes() > 0, "{label} memory ledger empty");
    }
}

#[test]
fn pretrain_mode_trains_embeddings() {
    let mut eng = engine();
    let cfg = RunConfig {
        model: "tiny".into(),
        method: MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.10, ..Default::default() },
            t_inner: 5,
            pretrain: true,
            ..Default::default()
        }),
        data: DataSpec::Lm,
        lr: 2e-3,
        steps: 10,
        pretrain: true,
        log_every: 100,
        ..Default::default()
    };
    let mut t = Trainer::new(&mut eng, cfg).unwrap();
    let embed_idx = t.sess.spec.param_index("embed").unwrap();
    let before = t.sess.host[embed_idx].clone();
    t.run(10).unwrap();
    let after = &t.sess.host[embed_idx];
    assert_ne!(&before, after, "embedding frozen in pretrain mode");
}

#[test]
fn kernel_and_host_paths_agree_over_misa_round() {
    // full MISA block epoch through the backend's fused entry points vs
    // the optimizer-side host loops: same seed, same data => numerically
    // identical parameters
    let mut eng = engine();
    let mk = |use_kernel: bool| RunConfig {
        model: "tiny".into(),
        method: MethodSpec::Misa(MisaConfig {
            sampler: SamplerConfig { delta: 0.08, ..Default::default() },
            t_inner: 4,
            use_kernel,
            kernel_min_elems: 0, // force the kernel path on tiny modules
            ..Default::default()
        }),
        data: DataSpec::Math,
        lr: 1e-3,
        steps: 8,
        use_kernel,
        log_every: 100,
        ..Default::default()
    };
    let mut a = Trainer::new(&mut eng, mk(true)).unwrap();
    let mut b = Trainer::new(&mut eng, mk(false)).unwrap();
    a.run(8).unwrap();
    b.run(8).unwrap();
    for (i, (pa, pb)) in a.sess.host.iter().zip(&b.sess.host).enumerate() {
        for (x, y) in pa.iter().zip(pb) {
            assert!(
                (x - y).abs() < 5e-5,
                "param {i} diverged between kernel and host paths: {x} vs {y}"
            );
        }
    }
}

#[test]
fn lisa_uses_more_sim_memory_than_badam() {
    // the paper's Tables 1/3/5 ordering, reproduced by the runtime
    // allocator ledger (LISA trains embed+head)
    let mut eng = engine();
    let run = |m: MethodSpec, eng: &mut Engine| {
        let cfg = RunConfig {
            model: "tiny".into(),
            method: m,
            data: DataSpec::Math,
            lr: 1e-3,
            steps: 4,
            log_every: 100,
            ..Default::default()
        };
        let mut t = Trainer::new(eng, cfg).unwrap();
        t.run(4).unwrap();
        t.alloc.peak_bytes()
    };
    let lisa = run(MethodSpec::Lisa { t_inner: 2 }, &mut eng);
    let badam = run(MethodSpec::BAdam { t_inner: 2 }, &mut eng);
    assert!(lisa > badam, "lisa {lisa} <= badam {badam}");
}

#[test]
fn checkpoint_roundtrip_through_session() {
    use misa::coordinator::ckpt;
    let mut eng = engine();
    let sess = Session::create(&mut eng, "tiny", 9).unwrap();
    let path = std::env::temp_dir().join(format!("misa_sess_ckpt_{}.bin", std::process::id()));
    ckpt::save(&path, &sess.host).unwrap();
    let loaded = ckpt::load(&path).unwrap();
    let spec = sess.spec.clone();
    let restored = Session::with_params(&mut eng, spec, loaded).unwrap();
    assert_eq!(restored.host, sess.host);
    let _ = std::fs::remove_file(&path);
}
