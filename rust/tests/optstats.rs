//! Training-telemetry integration tests: the headline claim is that
//! instrumentation is read-only — per-step losses are bit-identical
//! with the report collector on or off, across GEMM thread counts —
//! plus a structural smoke of the emitted JSON report and the
//! COW-aware KV residency measurement through a real scheduler run.

use std::sync::Mutex;

use misa::config::{MethodSpec, RunConfig};
use misa::coordinator::Trainer;
use misa::obs::{memory, metrics};
use misa::optim::MisaConfig;
use misa::runtime::{Engine, KvCache, Session};
use misa::serve::{CacheStoreCfg, Request, SamplerCfg, Scheduler, SchedulerCfg};
use misa::util::Rng;

/// The metrics registry, the byte-accounting atomics, and the GEMM
/// thread knob are process-global; serialize the tests that touch them.
static GATE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn misa_cfg(steps: u64) -> RunConfig {
    RunConfig {
        model: "tiny".into(),
        steps,
        seed: 42,
        log_every: 1, // train_loss lands in the sink every step
        method: MethodSpec::Misa(MisaConfig { t_inner: 2, ..MisaConfig::default() }),
        ..RunConfig::default()
    }
}

/// Run `steps` training steps one at a time, returning the per-step
/// loss sequence (exact f64s, no rounding).
fn run_losses(rc: &RunConfig, report: bool) -> Vec<f64> {
    let mut eng = Engine::host();
    let mut t = Trainer::new(&mut eng, rc.clone()).unwrap();
    if report {
        t.enable_report();
    }
    let mut losses = Vec::new();
    for _ in 0..rc.steps {
        t.run(1).unwrap();
        losses.push(t.metrics.last("train_loss").unwrap());
    }
    losses
}

/// Telemetry never perturbs computation: the per-step loss sequence is
/// bit-identical with report collection on or off, at GEMM widths 1
/// and 4 — the training-side twin of the decode bit-parity test.
#[test]
fn training_losses_bit_identical_with_report_on_and_off() {
    let _g = lock();
    let rc = misa_cfg(6);
    misa::tensor::set_threads(1);
    let base = run_losses(&rc, false);
    assert!(base.iter().all(|l| l.is_finite()), "{base:?}");
    for threads in [1usize, 4] {
        misa::tensor::set_threads(threads);
        for report in [false, true] {
            let got = run_losses(&rc, report);
            assert_eq!(
                got, base,
                "telemetry perturbed training (threads={threads}, report={report})"
            );
        }
    }
    misa::tensor::set_threads(0);
}

/// The structured report renders one valid-looking JSON object with
/// per-step variance + memory fields and a populated sampler section
/// (CI round-trips it through python's json.load).
#[test]
fn training_report_renders_per_step_and_summary_sections() {
    let _g = lock();
    let rc = misa_cfg(5);
    let mut eng = Engine::host();
    let mut t = Trainer::new(&mut eng, rc.clone()).unwrap();
    // writing before enabling is a hard error, not an empty file
    let path = std::env::temp_dir().join("misa_test_train_report.json");
    assert!(t.write_report(&path).is_err());
    t.enable_report();
    t.run(rc.steps).unwrap();
    t.write_report(&path).unwrap();
    let body = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(body.starts_with('{') && body.trim_end().ends_with('}'), "{body}");
    let braces =
        body.matches('{').count() as i64 - body.matches('}').count() as i64;
    assert_eq!(braces, 0, "unbalanced braces");
    for key in [
        "\"model\"",
        "\"method\"",
        "\"per_step\"",
        "\"loss\"",
        "\"var_sampled\"",
        "\"var_layerwise\"",
        "\"var_ratio\"",
        "\"optim_state_bytes\"",
        "\"activation_scratch_bytes\"",
        "\"summary\"",
        "\"variance\"",
        "\"sampler\"",
        "\"modules\"",
        "\"memory\"",
    ] {
        assert!(body.contains(key), "report missing {key}: {body}");
    }
    assert_eq!(
        body.matches("\"step\":").count(),
        rc.steps as usize,
        "one record per step: {body}"
    );
    assert!(!body.contains("NaN"), "non-finite values must render as null");
}

/// The scheduler's measured KV residency dedupes chunks shared
/// copy-on-write between live request rings and prompt-store entries:
/// with a shared system prefix, resident bytes stay strictly below the
/// per-entry analytic sum.
#[test]
fn scheduler_kv_residency_is_cow_deduped() {
    let _g = lock();
    metrics::reset();
    memory::reset();
    let mut eng = Engine::host();
    let sess = Session::create(&mut eng, "tiny", 3).unwrap();
    let store_cap = 256;
    let mut sched = Scheduler::new(SchedulerCfg {
        max_slots: 2,
        token_budget: 4096,
        prefix_cache: Some(CacheStoreCfg {
            capacity: store_cap,
            max_entries: 8,
            min_prefix: 4,
        }),
        prefill_chunk: 0,
        spec: None,
    });
    // 4 prompts sharing a 20-token system prefix, 4 unique tail tokens
    let mut rng = Rng::new(0xC0);
    let shared: Vec<i32> = std::iter::once(1)
        .chain((1..20).map(|_| rng.range(4, 200) as i32))
        .collect();
    for id in 0..4u64 {
        let mut prompt = shared.clone();
        for _ in 0..4 {
            prompt.push(rng.range(4, 200) as i32);
        }
        sched
            .submit(Request {
                id,
                prompt,
                max_new: 4,
                sampler: SamplerCfg::greedy(),
                seed: 90 + id,
                eos: None,
            })
            .unwrap();
    }
    let done = sched.run(&sess).unwrap();
    assert_eq!(done.len(), 4);
    let stats = sched.cache_stats().unwrap();
    assert!(stats.hits > 0, "shared prefixes must hit the store: {stats:?}");
    assert!(stats.entries >= 2);
    // every tick measured residency into the gauge + peak tracker
    assert!(memory::peak(memory::MemCategory::KvCache) > 0);
    assert!(metrics::gauge("serve.kv_resident_bytes").is_some());
    // after the run only store entries remain resident; their shared
    // prefix chunks are counted once, so measured < entries × ring
    let resident = sched.kv_resident_bytes();
    let per_ring = KvCache::bytes_for(&sess.spec, store_cap) as u64;
    assert!(resident > 0);
    assert!(
        resident < stats.entries as u64 * per_ring,
        "COW sharing must dedupe: {resident} vs {} naive",
        stats.entries as u64 * per_ring
    );
    // and the peak never exceeded what the rings could hold outright:
    // every live request ring plus every store entry at full ring size
    let bound = (4 + stats.insertions) * per_ring;
    assert!(
        memory::peak(memory::MemCategory::KvCache) <= bound,
        "peak {} above worst-case bound {bound}",
        memory::peak(memory::MemCategory::KvCache)
    );
}
