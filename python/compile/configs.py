"""Model / artifact configuration registry.

This file is the single source of truth for the parameter layout contract
between the Python compile path (L1/L2) and the Rust coordinator (L3).
`aot.py` serializes the registry into ``artifacts/manifest.txt`` which the
Rust side parses (see ``rust/src/modelspec/``). Order of parameters is a
hard ABI: the fwd/bwd graph takes params in registry order and returns
grads in the same order.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A LLaMA-architecture decoder LM configuration.

    The paper's module taxonomy (Sec. 3.3) maps onto this architecture:
    per transformer layer the sampled modules are W_q, W_k, W_v, W_o
    (attention) and W_gate, W_up, W_down (SwiGLU FFN); RMSNorm scales,
    the embedding and the LM head are separate parameters that MISA
    freezes during fine-tuning (Sec. 3.4, Table 2 footnote) and trains
    with dense Adam during pre-training (Sec. 5.4).
    """

    name: str
    vocab: int
    dim: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    ffn_dim: int
    seq_len: int
    batch: int
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.dim % self.n_heads == 0
        return self.dim // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


# Module kinds, mirroring the paper's taxonomy. "norm", "embed", "head"
# are parameters but not MISA sampling modules in fine-tuning.
KIND_NORM = "norm"
KIND_WQ = "wq"
KIND_WK = "wk"
KIND_WV = "wv"
KIND_WO = "wo"
KIND_WGATE = "wgate"
KIND_WUP = "wup"
KIND_WDOWN = "wdown"
KIND_EMBED = "embed"
KIND_HEAD = "head"

MATRIX_KINDS = (KIND_WQ, KIND_WK, KIND_WV, KIND_WO, KIND_WGATE, KIND_WUP, KIND_WDOWN)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter: the unit the Rust module registry tracks."""

    name: str
    kind: str
    layer: int  # -1 for non-layer params
    shape: Tuple[int, ...]

    @property
    def numel(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """The parameter registry, in ABI order."""
    specs: List[ParamSpec] = []
    d, f, v = cfg.dim, cfg.ffn_dim, cfg.vocab
    kd = cfg.kv_dim
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs.append(ParamSpec(p + "attn_norm", KIND_NORM, i, (d,)))
        specs.append(ParamSpec(p + "wq", KIND_WQ, i, (d, d)))
        specs.append(ParamSpec(p + "wk", KIND_WK, i, (d, kd)))
        specs.append(ParamSpec(p + "wv", KIND_WV, i, (d, kd)))
        specs.append(ParamSpec(p + "wo", KIND_WO, i, (d, d)))
        specs.append(ParamSpec(p + "mlp_norm", KIND_NORM, i, (d,)))
        specs.append(ParamSpec(p + "wgate", KIND_WGATE, i, (d, f)))
        specs.append(ParamSpec(p + "wup", KIND_WUP, i, (d, f)))
        specs.append(ParamSpec(p + "wdown", KIND_WDOWN, i, (f, d)))
    specs.append(ParamSpec("final_norm", KIND_NORM, -1, (d,)))
    specs.append(ParamSpec("embed", KIND_EMBED, -1, (v, d)))
    specs.append(ParamSpec("head", KIND_HEAD, -1, (d, v)))
    return specs


def total_params(cfg: ModelConfig) -> int:
    return sum(s.numel for s in param_specs(cfg))


def unique_matrix_shapes(cfg: ModelConfig) -> List[Tuple[int, ...]]:
    """Distinct trainable shapes → one fused-Adam artifact per shape."""
    seen = []
    for s in param_specs(cfg):
        if s.shape not in seen:
            seen.append(s.shape)
    return seen


# ---------------------------------------------------------------------------
# The artifact set. Sizes are scaled-down substitutes for the paper's
# testbeds (see DESIGN.md Sec. 4): "tiny" drives tests, "small" drives the
# fine-tuning tables, "pt130"/"pt350" are the pre-training analogues of
# LLaMA2-130M/350M (Table 6 / Fig. 4), "e2e" is the ~100M-parameter
# end-to-end training example required by examples/pretrain_e2e.rs.
# ---------------------------------------------------------------------------

CONFIGS: List[ModelConfig] = [
    ModelConfig("tiny", vocab=256, dim=64, n_layers=2, n_heads=4, n_kv_heads=2,
                ffn_dim=176, seq_len=32, batch=4),
    ModelConfig("small", vocab=512, dim=128, n_layers=4, n_heads=4, n_kv_heads=2,
                ffn_dim=344, seq_len=64, batch=8),
    ModelConfig("pt130", vocab=1024, dim=192, n_layers=4, n_heads=6, n_kv_heads=3,
                ffn_dim=512, seq_len=64, batch=8),
    ModelConfig("pt350", vocab=1024, dim=320, n_layers=6, n_heads=8, n_kv_heads=4,
                ffn_dim=864, seq_len=64, batch=8),
    ModelConfig("e2e", vocab=8192, dim=768, n_layers=12, n_heads=12, n_kv_heads=6,
                ffn_dim=2048, seq_len=64, batch=4),
]


def get_config(name: str) -> ModelConfig:
    for c in CONFIGS:
        if c.name == name:
            return c
    raise KeyError(f"unknown config {name!r}")
