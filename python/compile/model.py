"""L2: LLaMA-architecture decoder LM forward/backward in JAX.

Build-time only. The three graphs lowered by aot.py are:

  fwd_bwd(params..., tokens, targets, mask)
      -> (loss, grads... [registry order], sq_norms f32[P])
  predict(params..., tokens, targets, mask)
      -> (loss, correct f32[b,s])
  (per-shape) adam_step / momentum_tail — see kernels/fused_adam.py

The parameter order contract lives in configs.param_specs; grads are
returned in the same order so the Rust coordinator can zip them against
its module registry. sq_norms are the per-parameter squared Frobenius
norms computed by the Pallas sq_norm kernel inside the same graph —
the importance indicator is a by-product of the backward pass.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, ParamSpec, param_specs
from .kernels.sq_norm import sq_norm


# ---------------------------------------------------------------------------
# Initialization (mirrored in Rust for seed-compatible host init; the Rust
# side owns the canonical init — this one is used by python tests).
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out = []
    for spec in param_specs(cfg):
        if spec.kind == "norm":
            out.append(jnp.ones(spec.shape, jnp.float32))
        else:
            fan_in = spec.shape[0]
            std = 0.02 if spec.kind in ("embed", "head") else fan_in ** -0.5
            out.append(jnp.asarray(
                rng.normal(0.0, std, size=spec.shape), jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rms_norm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    pos = np.arange(cfg.seq_len, dtype=np.float32)
    freqs = cfg.rope_theta ** (-np.arange(0, hd, 2, dtype=np.float32) / hd)
    ang = np.outer(pos, freqs)  # [s, hd/2]
    return jnp.asarray(np.cos(ang)), jnp.asarray(np.sin(ang))


def _apply_rope(x, cos, sin):
    # x: [b, s, n, hd]; rotate pairs (even, odd)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    ro = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return ro.reshape(x.shape)


def _as_dict(cfg: ModelConfig, params: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {s.name: p for s, p in zip(param_specs(cfg), params)}


def forward_logits(cfg: ModelConfig, params: List[jnp.ndarray], tokens):
    """tokens i32[b,s] -> logits f32[b,s,V]."""
    p = _as_dict(cfg, params)
    b, s = tokens.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cos, sin = _rope_tables(cfg)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    x = p["embed"][tokens]  # [b,s,d]
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        h = _rms_norm(x, p[pre + "attn_norm"])
        q = (h @ p[pre + "wq"]).reshape(b, s, nh, hd)
        k = (h @ p[pre + "wk"]).reshape(b, s, nkv, hd)
        v = (h @ p[pre + "wv"]).reshape(b, s, nkv, hd)
        q = _apply_rope(q, cos, sin)
        k = _apply_rope(k, cos, sin)
        # GQA: repeat kv heads
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(causal[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.dim)
        x = x + o @ p[pre + "wo"]
        h = _rms_norm(x, p[pre + "mlp_norm"])
        gate = jax.nn.silu(h @ p[pre + "wgate"])
        up = h @ p[pre + "wup"]
        x = x + (gate * up) @ p[pre + "wdown"]
    x = _rms_norm(x, p["final_norm"])
    return x @ p["head"]


def masked_loss(cfg: ModelConfig, params: List[jnp.ndarray], tokens, targets,
                mask):
    """Mean masked next-token cross-entropy.

    tokens/targets i32[b,s]; mask f32[b,s] selects supervised positions
    (1 everywhere for pre-training; answer span only for fine-tuning).
    """
    logits = forward_logits(cfg, params, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = logz - gold
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce * mask) / denom


def build_fwd_bwd(cfg: ModelConfig):
    """The training graph: loss + all grads + per-param squared norms."""

    def fwd_bwd(params, tokens, targets, mask):
        loss, grads = jax.value_and_grad(
            lambda ps: masked_loss(cfg, ps, tokens, targets, mask))(params)
        norms = jnp.stack([sq_norm(g) for g in grads])
        return (loss, *grads, norms)

    return fwd_bwd


def build_predict(cfg: ModelConfig):
    """Evaluation graph: masked loss + per-position teacher-forced hits."""

    def predict(params, tokens, targets, mask):
        logits = forward_logits(cfg, params, tokens)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = logz - gold
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(ce * mask) / denom
        correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
        return loss, correct

    return predict
