"""L1 Pallas kernel: scaled squared-gradient-norm reduction.

The importance sampler (paper Eq. 4 + Appendix A.2) scores each module by
its *scaled gradient norm* ||g||_F / sqrt(|m|). This kernel computes the
squared Frobenius norm of a module gradient in one tiled pass; the Rust
coordinator divides by the parameter count (the scaling) and feeds the
EMA tracker G_b. It is embedded in the fwd/bwd graph (model.py) so the
indicator is a by-product of the backward pass — paper Appendix F.3's
"negligible overhead" claim made structural.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 131072  # 512 KiB of f32 per tile


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _sq_norm_kernel(g_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...]
    acc_ref[...] += jnp.sum(g * g)


@jax.jit
def sq_norm(g):
    """sum(g*g) over an arbitrary-shaped f32 array, tiled 1-D."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    block = min(BLOCK, n)
    # pad so the grid covers the array exactly (zeros do not affect the sum)
    padded = _cdiv(n, block) * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    grid = (padded // block,)
    out = pl.pallas_call(
        _sq_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(())


def scaled_sq_norm(g):
    """||g||_F^2 / |m| — the squared scaled gradient norm of Appendix A.2."""
    return sq_norm(g) / jnp.float32(g.size)
