"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: python/tests/test_kernel.py
asserts allclose(kernel, ref) across hypothesis-generated shapes and
hyper-parameters. Keep these boring and obviously correct.
"""

from __future__ import annotations

import jax.numpy as jnp


def adam_ref(p, g, m, v, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Reference Adam step per paper Algorithm 1 lines 9-11 (no bias corr.)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    p_new = p - lr * m_new / (jnp.sqrt(v_new) + eps)
    return p_new, m_new, v_new, jnp.sum(g * g)


def momentum_tail_ref(p, m, v, lr, beta1=0.9, eps=1e-8):
    """Reference for Algorithm 1 line 16 (additional momentum step)."""
    return p - lr * (beta1 / (1.0 - beta1)) * m / (jnp.sqrt(v) + eps)


def sq_norm_ref(g):
    return jnp.sum(g * g)


def scaled_sq_norm_ref(g):
    return jnp.sum(g * g) / jnp.float32(g.size)


def softmax_probs_ref(scores, eta):
    z = scores * eta
    z = z - jnp.max(z)
    e = jnp.exp(z)
    return e / jnp.sum(e)
