"""L1 Pallas kernel: fused MISA/Adam module update.

The paper's inner loop (Algorithm 1, lines 8-11) performs, per sampled
module and per inner step t:

    m <- b1*m + (1-b1)*g
    v <- b2*v + (1-b2)*g^2
    p <- p - lr * m / (sqrt(v) + eps)          (no bias correction)

plus, at the end of a block epoch, the *additional momentum step*
(line 16):

    p <- p - lr * (b1/(1-b1)) * m / (sqrt(v) + eps)

and the analytical variant (Algorithm 3, line 12) uses an AMSGrad-type
running max of v.

On GPU these are 3-4 separate memory-bound elementwise passes; the TPU
adaptation (DESIGN.md §Hardware-Adaptation) fuses them into a single
HBM->VMEM->HBM sweep tiled by BlockSpec, and accumulates the squared
gradient norm needed by the importance sampler (Eq. 4) as a free
by-product of the same pass — this is the structural realization of the
paper's "indicator overhead is negligible" claim (Appendix F.3).

All kernels are lowered with interpret=True so the CPU PJRT client can
execute the resulting HLO (real TPU lowering emits a Mosaic custom call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM plan: 4 resident operand tiles (p, g, m, v) + 3 result tiles.
# 256x512 f32 = 512 KiB/tile -> 3.5 MiB resident, comfortably under the
# ~16 MiB VMEM budget and large enough to amortize the HBM latency.
BLOCK_R = 256
BLOCK_C = 512


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, po_ref, mo_ref, vo_ref,
                 acc_ref, *, beta1: float, beta2: float, eps: float,
                 rows: int, cols: int):
    """One fused tile update; acc_ref accumulates sum(g^2) across the grid."""
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mo_ref[...] = m
    vo_ref[...] = v
    po_ref[...] = p_ref[...] - lr_ref[0] * m / (jnp.sqrt(v) + eps)

    # grid iterations run sequentially on TPU; accumulate the norm
    # by-product into a (1,1) output block shared by every tile. Ragged
    # edge tiles carry undefined padding, so mask by the global index.
    br, bc = g.shape
    r0 = pl.program_id(0) * br
    c0 = pl.program_id(1) * bc
    rid = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cid = c0 + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    gm = jnp.where((rid < rows) & (cid < cols), g, 0.0)

    @pl.when(_is_first_tile())
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(gm * gm)


def _is_first_tile():
    idx = [pl.program_id(i) for i in range(2)]
    return jnp.logical_and(idx[0] == 0, idx[1] == 0)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps"))
def fused_adam(p, g, m, v, lr, *, beta1: float = 0.9, beta2: float = 0.999,
               eps: float = 1e-8):
    """Fused Adam step on a module matrix (or vector).

    Args:
      p, g, m, v: same-shaped f32 arrays (param, grad, 1st/2nd moment).
      lr: f32[1] learning rate (runtime input so Rust can schedule it).

    Returns:
      (p_new, m_new, v_new, sq_norm) where sq_norm is f32[] = sum(g*g).
    """
    orig_shape = p.shape
    # Normalize to 2-D so one kernel serves matrices and norm vectors.
    if p.ndim == 1:
        p2, g2, m2, v2 = (x.reshape(1, -1) for x in (p, g, m, v))
    else:
        p2, g2, m2, v2 = p, g, m, v
    rows, cols = p2.shape
    br = min(BLOCK_R, rows)
    bc = min(BLOCK_C, cols)
    grid = (_cdiv(rows, br), _cdiv(cols, bc))
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1,), lambda i, j: (0,))
    acc = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    kernel = functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps,
                               rows=rows, cols=cols)
    po, mo, vo, sq = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, scalar],
        out_specs=[tile, tile, tile, acc],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(p2, g2, m2, v2, lr)
    return (po.reshape(orig_shape), mo.reshape(orig_shape),
            vo.reshape(orig_shape), sq.reshape(()))


def _momentum_tail_kernel(p_ref, m_ref, v_ref, lr_ref, po_ref, *,
                          beta1: float, eps: float):
    c1 = beta1 / (1.0 - beta1)
    po_ref[...] = p_ref[...] - lr_ref[0] * c1 * m_ref[...] / (
        jnp.sqrt(v_ref[...]) + eps)


@functools.partial(jax.jit, static_argnames=("beta1", "eps"))
def momentum_tail(p, m, v, lr, *, beta1: float = 0.9, eps: float = 1e-8):
    """Algorithm 1 line 16: the additional momentum step at epoch end."""
    orig_shape = p.shape
    if p.ndim == 1:
        p2, m2, v2 = (x.reshape(1, -1) for x in (p, m, v))
    else:
        p2, m2, v2 = p, m, v
    rows, cols = p2.shape
    br = min(BLOCK_R, rows)
    bc = min(BLOCK_C, cols)
    grid = (_cdiv(rows, br), _cdiv(cols, bc))
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1,), lambda i, j: (0,))
    kernel = functools.partial(_momentum_tail_kernel, beta1=beta1, eps=eps)
    po = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(p2, m2, v2, lr)
    return po.reshape(orig_shape)
