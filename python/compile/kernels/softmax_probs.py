"""L1 Pallas kernel: tempered-softmax sampling probabilities (paper Eq. 3).

    p_b = exp(eta * G_b) / sum_j exp(eta * G_j)

computed in a numerically stable single block (the module count B is tiny
— a few hundred — so one VMEM block always suffices). The Rust sampler
calls the AOT artifact of this kernel each outer round; it is the KL-
regularized importance distribution of Proposition 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(g_ref, eta_ref, p_ref):
    g = g_ref[...] * eta_ref[0]
    g = g - jnp.max(g)
    e = jnp.exp(g)
    p_ref[...] = e / jnp.sum(e)


@jax.jit
def softmax_probs(scores, eta):
    """Tempered softmax over the module importance scores.

    Args:
      scores: f32[B] smoothed scaled gradient norms G_b.
      eta: f32[1] exploration/exploitation temperature (eta→0 uniform).

    Returns:
      f32[B] simplex-valued sampling probabilities.
    """
    (b,) = scores.shape
    return pl.pallas_call(
        _softmax_kernel,
        in_specs=[pl.BlockSpec((b,), lambda: (0,)),
                  pl.BlockSpec((1,), lambda: (0,))],
        out_specs=pl.BlockSpec((b,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(scores, eta)
