"""AOT driver: lower every (config, graph) pair to HLO *text* artifacts.

Run once by `make artifacts`; the Rust binary is self-contained afterwards.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Outputs, per config C:
  artifacts/C.fwd_bwd.hlo.txt        training step graph
  artifacts/C.predict.hlo.txt        eval graph
  artifacts/C.adam.RxC.hlo.txt       fused-Adam update per distinct shape
  artifacts/C.tail.RxC.hlo.txt       additional momentum step per shape
  artifacts/probs.B.hlo.txt          sampler softmax (per module-count B)
  artifacts/manifest.txt             the L3 ABI: configs, params, graphs
"""

from __future__ import annotations

import argparse
import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, ModelConfig, param_specs
from .kernels.fused_adam import fused_adam, momentum_tail
from .kernels.softmax_probs import softmax_probs
from .model import build_fwd_bwd, build_predict

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _exists(path: str) -> bool:
    return os.path.exists(path) and os.path.getsize(path) > 0


def _write(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)


def shape_key(shape) -> str:
    return "x".join(str(d) for d in shape)


def lower_config(cfg: ModelConfig, outdir: str, manifest: list,
                 skip_existing: bool = False) -> None:
    t0 = time.time()
    specs = param_specs(cfg)
    pspecs = [jax.ShapeDtypeStruct(s.shape, F32) for s in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), I32)
    msk = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), F32)

    manifest.append(f"config {cfg.name}")
    for key in ("vocab", "dim", "n_layers", "n_heads", "n_kv_heads",
                "ffn_dim", "seq_len", "batch"):
        manifest.append(f"  field {key} {getattr(cfg, key)}")
    for s in specs:
        dims = " ".join(str(d) for d in s.shape)
        manifest.append(f"  param {s.name} {s.kind} {s.layer} {len(s.shape)} {dims}")

    # --- training graph -------------------------------------------------
    fname = f"{cfg.name}.fwd_bwd.hlo.txt"
    if not (skip_existing and _exists(os.path.join(outdir, fname))):
        fwd_bwd = build_fwd_bwd(cfg)
        lowered = jax.jit(fwd_bwd).lower(pspecs, tok, tok, msk)
        _write(os.path.join(outdir, fname), to_hlo_text(lowered))
    manifest.append(f"  graph fwd_bwd {fname}")

    # --- eval graph ------------------------------------------------------
    fname = f"{cfg.name}.predict.hlo.txt"
    if not (skip_existing and _exists(os.path.join(outdir, fname))):
        predict = build_predict(cfg)
        lowered = jax.jit(predict).lower(pspecs, tok, tok, msk)
        _write(os.path.join(outdir, fname), to_hlo_text(lowered))
    manifest.append(f"  graph predict {fname}")

    # --- optimizer kernels, one per distinct param shape ------------------
    seen = set()
    for s in specs:
        key = shape_key(s.shape)
        if key in seen:
            continue
        seen.add(key)
        arr = jax.ShapeDtypeStruct(s.shape, F32)
        lr = jax.ShapeDtypeStruct((1,), F32)
        fname = f"{cfg.name}.adam.{key}.hlo.txt"
        if not (skip_existing and _exists(os.path.join(outdir, fname))):
            lowered = jax.jit(
                functools.partial(fused_adam, beta1=0.9, beta2=0.999, eps=1e-8)
            ).lower(arr, arr, arr, arr, lr)
            _write(os.path.join(outdir, fname), to_hlo_text(lowered))
        manifest.append(f"  graph adam.{key} {fname}")

        fname = f"{cfg.name}.tail.{key}.hlo.txt"
        if not (skip_existing and _exists(os.path.join(outdir, fname))):
            lowered = jax.jit(
                functools.partial(momentum_tail, beta1=0.9, eps=1e-8)
            ).lower(arr, arr, arr, lr)
            _write(os.path.join(outdir, fname), to_hlo_text(lowered))
        manifest.append(f"  graph tail.{key} {fname}")

    print(f"config {cfg.name}: lowered in {time.time() - t0:.1f}s", flush=True)


def lower_probs(outdir: str, manifest: list, sizes, skip_existing=False) -> None:
    """Sampler softmax artifacts, one per module-count the L3 sampler uses."""
    for b in sorted(set(sizes)):
        fname = f"probs.{b}.hlo.txt"
        if not (skip_existing and _exists(os.path.join(outdir, fname))):
            scores = jax.ShapeDtypeStruct((b,), F32)
            eta = jax.ShapeDtypeStruct((1,), F32)
            lowered = jax.jit(softmax_probs).lower(scores, eta)
            _write(os.path.join(outdir, fname), to_hlo_text(lowered))
        manifest.append(f"probs {b} {fname}")


def n_matrix_modules(cfg: ModelConfig) -> int:
    return sum(1 for s in param_specs(cfg)
               if s.kind not in ("norm", "embed", "head"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--configs", default="",
                    help="comma-separated config names (default: all)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="do not re-lower graphs whose artifact file exists")
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)

    wanted = [c for c in CONFIGS
              if not args.configs or c.name in args.configs.split(",")]
    manifest: list = ["version 1"]
    for cfg in wanted:
        lower_config(cfg, outdir, manifest, skip_existing=args.skip_existing)
    # probs artifacts: sampler operates over matrix modules only (fine-tune)
    # or all params (pre-train); emit both sizes per config.
    sizes = []
    for cfg in wanted:
        sizes.append(n_matrix_modules(cfg))
        sizes.append(len(param_specs(cfg)))
    lower_probs(outdir, manifest, sizes, skip_existing=args.skip_existing)
    _write(os.path.join(outdir, "manifest.txt"), "\n".join(manifest) + "\n")
    print("AOT done.", flush=True)


if __name__ == "__main__":
    main()
