"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes, dtypes-compatible value ranges and
hyper-parameters; every kernel must match its reference to f32 tolerance
across single- and multi-tile grids.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_adam import (BLOCK_C, BLOCK_R, fused_adam,
                                        momentum_tail)
from compile.kernels.softmax_probs import softmax_probs
from compile.kernels.sq_norm import BLOCK as SQ_BLOCK
from compile.kernels.sq_norm import scaled_sq_norm, sq_norm

ATOL = 1e-5
RTOL = 1e-5


def _mats(rng, shape):
    p = rng.normal(size=shape).astype(np.float32)
    g = rng.normal(size=shape).astype(np.float32)
    m = rng.normal(size=shape).astype(np.float32)
    v = np.abs(rng.normal(size=shape)).astype(np.float32)  # 2nd moment >= 0
    return p, g, m, v


shapes = st.sampled_from([
    (8,), (130,), (1, 1), (3, 7), (64, 64), (70, 130),
    (BLOCK_R, BLOCK_C),             # exactly one tile
    (BLOCK_R + 5, BLOCK_C + 3),     # ragged multi-tile grid
    (2 * BLOCK_R, 17),              # tall
])


class TestFusedAdam:
    @settings(max_examples=20, deadline=None)
    @given(shape=shapes,
           lr=st.floats(1e-6, 1e-1),
           beta1=st.floats(0.0, 0.99),
           beta2=st.floats(0.5, 0.9999),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, lr, beta1, beta2, seed):
        rng = np.random.default_rng(seed)
        p, g, m, v = _mats(rng, shape)
        lr_arr = jnp.asarray([lr], jnp.float32)
        po, mo, vo, sq = fused_adam(p, g, m, v, lr_arr,
                                    beta1=beta1, beta2=beta2)
        pr, mr, vr, sr = ref.adam_ref(p, g, m, v, lr,
                                      beta1=beta1, beta2=beta2)
        np.testing.assert_allclose(mo, mr, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(vo, vr, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(po, pr, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(sq, sr, rtol=1e-4)

    def test_zero_grad_keeps_param_moving_by_momentum_only(self):
        rng = np.random.default_rng(0)
        p, _, m, v = _mats(rng, (16, 16))
        g = np.zeros_like(p)
        lr = jnp.asarray([0.1], jnp.float32)
        po, mo, vo, sq = fused_adam(p, g, m, v, lr)
        assert float(sq) == 0.0
        np.testing.assert_allclose(mo, 0.9 * m, atol=ATOL)

    def test_multi_tile_norm_accumulation(self):
        # the sq-norm by-product must sum across ALL grid tiles
        rng = np.random.default_rng(1)
        shape = (BLOCK_R + 1, BLOCK_C + 1)  # 4 tiles
        p, g, m, v = _mats(rng, shape)
        lr = jnp.asarray([0.01], jnp.float32)
        _, _, _, sq = fused_adam(p, g, m, v, lr)
        np.testing.assert_allclose(float(sq), float(np.sum(g * g)), rtol=1e-4)


class TestMomentumTail:
    @settings(max_examples=15, deadline=None)
    @given(shape=shapes, lr=st.floats(1e-6, 1e-1),
           beta1=st.floats(0.01, 0.99), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, lr, beta1, seed):
        rng = np.random.default_rng(seed)
        p, _, m, v = _mats(rng, shape)
        po = momentum_tail(p, m, v, jnp.asarray([lr], jnp.float32),
                           beta1=beta1)
        pr = ref.momentum_tail_ref(p, m, v, lr, beta1=beta1)
        np.testing.assert_allclose(po, pr, atol=1e-4, rtol=1e-4)


class TestSqNorm:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([1, 7, 1024, SQ_BLOCK, SQ_BLOCK + 1,
                              2 * SQ_BLOCK + 13]),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        g = rng.normal(size=(n,)).astype(np.float32)
        np.testing.assert_allclose(float(sq_norm(g)),
                                   float(ref.sq_norm_ref(g)), rtol=1e-4)

    def test_2d_and_scaling(self):
        rng = np.random.default_rng(2)
        g = rng.normal(size=(37, 53)).astype(np.float32)
        np.testing.assert_allclose(float(scaled_sq_norm(g)),
                                   float(np.sum(g * g)) / g.size, rtol=1e-4)

    def test_zeros(self):
        assert float(sq_norm(np.zeros(100, np.float32))) == 0.0


class TestSoftmaxProbs:
    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 300), eta=st.floats(0.0, 300.0),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_and_simplex(self, b, eta, seed):
        rng = np.random.default_rng(seed)
        s = np.abs(rng.normal(size=(b,))).astype(np.float32)
        p = np.asarray(softmax_probs(s, jnp.asarray([eta], jnp.float32)))
        pr = np.asarray(ref.softmax_probs_ref(s, eta))
        np.testing.assert_allclose(p, pr, atol=1e-6)
        assert abs(p.sum() - 1.0) < 1e-5
        assert (p >= 0).all()

    def test_eta_zero_is_uniform(self):
        # paper Sec 3.2: eta -> 0 recovers uniform sampling
        s = np.asarray([0.1, 5.0, 2.0], np.float32)
        p = np.asarray(softmax_probs(s, jnp.asarray([0.0], jnp.float32)))
        np.testing.assert_allclose(p, np.full(3, 1 / 3), atol=1e-6)

    def test_large_eta_concentrates(self):
        # eta -> inf recovers greedy importance sampling (Prop. 1 limit)
        s = np.asarray([0.1, 5.0, 2.0], np.float32)
        p = np.asarray(softmax_probs(s, jnp.asarray([200.0], jnp.float32)))
        assert p[1] > 0.999

    def test_stability_large_scores(self):
        s = np.asarray([1e4, 1e4 + 1], np.float32)
        p = np.asarray(softmax_probs(s, jnp.asarray([1.0], jnp.float32)))
        assert np.isfinite(p).all()
