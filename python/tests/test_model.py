"""L2 model correctness: shapes, gradient integrity, loss semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import get_config, param_specs, total_params
from compile.model import (build_fwd_bwd, build_predict, forward_logits,
                           init_params, masked_loss)

CFG = get_config("tiny")


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)),
                      jnp.int32)
    tgt = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)),
                      jnp.int32)
    msk = jnp.ones((CFG.batch, CFG.seq_len), jnp.float32)
    return tok, tgt, msk


def test_param_registry_counts():
    specs = param_specs(CFG)
    # per layer: 2 norms + 7 matrices; plus final_norm, embed, head
    assert len(specs) == CFG.n_layers * 9 + 3
    assert total_params(CFG) == sum(s.numel for s in specs)
    # the paper's 7 module kinds all present
    kinds = {s.kind for s in specs}
    for k in ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown"):
        assert k in kinds


def test_logits_shape(params, batch):
    tok, _, _ = batch
    logits = forward_logits(CFG, params, tok)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert jnp.isfinite(logits).all()


def test_initial_loss_near_uniform(params, batch):
    tok, tgt, msk = batch
    loss = masked_loss(CFG, params, tok, tgt, msk)
    # random init => loss ~ ln(V)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_mask_selects_positions(params, batch):
    tok, tgt, _ = batch
    m0 = jnp.zeros((CFG.batch, CFG.seq_len), jnp.float32)
    m0 = m0.at[:, :4].set(1.0)
    full = masked_loss(CFG, params, tok, tgt,
                       jnp.ones((CFG.batch, CFG.seq_len), jnp.float32))
    part = masked_loss(CFG, params, tok, tgt, m0)
    assert float(full) != float(part)


def test_causality(params):
    # changing a future token must not change earlier logits
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, (1, CFG.seq_len)), jnp.int32)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % CFG.vocab)
    l1 = forward_logits(CFG, params, tok)
    l2 = forward_logits(CFG, params, tok2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)


def test_fwd_bwd_outputs(params, batch):
    tok, tgt, msk = batch
    out = build_fwd_bwd(CFG)(params, tok, tgt, msk)
    specs = param_specs(CFG)
    assert len(out) == 1 + len(specs) + 1
    loss, grads, norms = out[0], out[1:-1], out[-1]
    assert norms.shape == (len(specs),)
    for g, s in zip(grads, specs):
        assert g.shape == s.shape, s.name
    # sq-norm output equals actual grad norms (Pallas kernel in-graph)
    ref = np.asarray([float(jnp.sum(g * g)) for g in grads])
    np.testing.assert_allclose(np.asarray(norms), ref, rtol=1e-4, atol=1e-6)


def test_grad_finite_difference(params, batch):
    # spot-check one matrix entry per module kind against finite differences
    tok, tgt, msk = batch
    specs = param_specs(CFG)
    f = lambda ps: masked_loss(CFG, ps, tok, tgt, msk)
    grads = jax.grad(f)(params)
    eps = 1e-3
    checked = set()
    for i, s in enumerate(specs):
        if s.kind in checked or s.kind == "norm":
            continue
        checked.add(s.kind)
        idx = tuple(0 for _ in s.shape)
        bump = jnp.zeros(s.shape, jnp.float32).at[idx].set(eps)
        plus = list(params)
        plus[i] = params[i] + bump
        minus = list(params)
        minus[i] = params[i] - bump
        fd = (float(f(plus)) - float(f(minus))) / (2 * eps)
        g = float(grads[i][idx])
        assert abs(fd - g) < 5e-2 * max(1.0, abs(g)), (s.name, fd, g)


def test_predict_correct_mask(params, batch):
    tok, tgt, msk = batch
    loss, correct = build_predict(CFG)(params, tok, tgt, msk)
    assert correct.shape == (CFG.batch, CFG.seq_len)
    assert ((correct == 0.0) | (correct == 1.0)).all()
    # predicting the argmax targets makes everything correct
    logits = forward_logits(CFG, params, tok)
    best = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    _, c2 = build_predict(CFG)(params, tok, best, msk)
    assert float(c2.mean()) == 1.0


def test_gqa_head_config():
    assert CFG.n_heads % CFG.n_kv_heads == 0
    assert CFG.kv_dim == CFG.n_kv_heads * CFG.head_dim


def test_training_reduces_loss(params, batch):
    # 20 plain-SGD steps on the full model must reduce the loss — the
    # smoke-level guarantee the optimizer substrate builds on.
    tok, tgt, msk = batch
    f = lambda ps: masked_loss(CFG, ps, tok, tgt, msk)
    vg = jax.jit(jax.value_and_grad(f))
    ps = list(params)
    first, last = None, None
    for _ in range(20):
        loss, grads = vg(ps)
        if first is None:
            first = float(loss)
        ps = [p - 0.5 * g for p, g in zip(ps, grads)]
        last = float(loss)
    assert last < first
