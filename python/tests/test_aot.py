"""AOT pipeline checks: manifest ABI consistency and HLO-text format.

These validate the build products when `make artifacts` has run (skipped
otherwise) plus the manifest-generation logic itself, which must match
the Rust parser's expectations line for line.
"""

import os

import pytest

from compile.configs import CONFIGS, get_config, param_specs, total_params
from compile.aot import n_matrix_modules, shape_key

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.txt")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_config_registry_sanity():
    names = [c.name for c in CONFIGS]
    assert len(names) == len(set(names))
    for c in CONFIGS:
        assert c.dim % c.n_heads == 0
        assert c.n_heads % c.n_kv_heads == 0
        assert c.vocab >= 64  # reserved token space
        specs = param_specs(c)
        # ABI: per layer 9 params, plus final_norm/embed/head
        assert len(specs) == c.n_layers * 9 + 3
        assert total_params(c) == sum(s.numel for s in specs)


def test_e2e_config_is_about_100m_params():
    cfg = get_config("e2e")
    assert 50e6 < total_params(cfg) < 150e6


def test_shape_key_format():
    assert shape_key((64, 32)) == "64x32"
    assert shape_key((128,)) == "128"


def test_matrix_module_count():
    cfg = get_config("tiny")
    assert n_matrix_modules(cfg) == cfg.n_layers * 7


@needs_artifacts
def test_manifest_lists_every_graph_file():
    with open(MANIFEST) as f:
        lines = [l.strip() for l in f if l.strip()]
    assert lines[0] == "version 1"
    files = [l.split()[-1] for l in lines if l.startswith(("graph", "probs"))]
    assert files, "no graphs in manifest"
    for fname in files:
        path = os.path.join(ARTIFACTS, fname)
        assert os.path.exists(path), fname
        assert os.path.getsize(path) > 0, fname


@needs_artifacts
def test_hlo_text_is_parseable_hlo():
    # spot-check: HLO text modules start with the HloModule header that
    # xla::HloModuleProto::from_text_file expects
    with open(MANIFEST) as f:
        fname = next(l.split()[-1] for l in f if l.strip().startswith("graph"))
    with open(os.path.join(ARTIFACTS, fname)) as f:
        head = f.read(200)
    assert head.startswith("HloModule"), head[:50]


@needs_artifacts
def test_manifest_param_order_matches_registry():
    with open(MANIFEST) as f:
        text = f.read()
    for cfg in CONFIGS:
        if f"config {cfg.name}\n" not in text:
            continue
        section = text.split(f"config {cfg.name}\n", 1)[1]
        manifest_params = []
        for line in section.splitlines():
            line = line.strip()
            if line.startswith("param "):
                manifest_params.append(line.split()[1])
            elif line.startswith("config "):
                break
        expected = [s.name for s in param_specs(cfg)]
        assert manifest_params[: len(expected)] == expected, cfg.name
